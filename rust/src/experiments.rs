//! The §6 system experiments (Experiments 1–6), shared by the CLI
//! (`unilrc experiment N`) and the bench harness (`cargo bench`), plus
//! Experiment 7 — the deterministic fault-injection scenario runner that
//! replays seeded failure schedules ([`crate::sim::faults`]) against the
//! prototype and cross-checks the measurements with the closed-form
//! reliability model ([`crate::analysis::markov`]).
//!
//! Each driver builds a DSS per code family on the virtual testbed
//! (DESIGN.md §5) and reports the same quantity the paper's figure plots.

use crate::analysis::markov;
use crate::client::workload::{Workload, WorkloadSpec};
use crate::client::{cdf_points, mean};
use crate::codes::spec::{CodeFamily, Scheme};
use crate::coordinator::manifest::{MANIFEST_CURRENT, MANIFEST_PREV};
use crate::coordinator::wal::{list_segments, scan_segment, ScanEnd};
use crate::coordinator::{
    recover, BackoffPolicy, BlockState, Dss, DssConfig, DurabilityOptions, ManifestStore,
    MigrationError, MigrationReport, MigrationStats, StripeId,
};
use crate::placement::{EcWide, PlacementStrategy, Topology, TopologyEvent, UniLrcPlace};
use crate::prng::Prng;
use crate::runtime::{CodingEngine, NativeCoder, PjrtCoder};
use crate::sim::faults::{
    digest_mix, replay_scrub, DownState, FaultConfig, FaultKind, FaultTrace, ScrubConfig,
    DIGEST_SEED,
};
use crate::sim::{Endpoint, NetConfig};
use anyhow::Result;
use std::sync::Arc;

/// Decode-plan warm-up policy for the fault scenarios (experiment 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarmupMode {
    /// No prefetch — every plan is built on demand.
    Off,
    /// Prefetch the patterns predicted from the *known* fault trace before
    /// replay starts ([`predicted_patterns`]).
    Trace,
    /// Learn online: as the replay observes failures through the
    /// [`DownState`] history, prefetch the patterns their recurrence would
    /// produce ([`PatternPredictor`]) — no prior knowledge of the trace.
    Learned,
}

impl WarmupMode {
    pub fn name(&self) -> &'static str {
        match self {
            WarmupMode::Off => "off",
            WarmupMode::Trace => "trace",
            WarmupMode::Learned => "learned",
        }
    }

    /// Parse a `--plan-warmup` value (`true`/bare = trace for backwards
    /// compatibility).
    pub fn parse(s: &str) -> Option<WarmupMode> {
        match s {
            "off" | "false" => Some(WarmupMode::Off),
            "trace" | "true" => Some(WarmupMode::Trace),
            "learned" => Some(WarmupMode::Learned),
            _ => None,
        }
    }
}

/// Experiment configuration (defaults shrink the paper's 1 MB / 40 GB
/// scale to bench-friendly sizes; all knobs are CLI-exposed).
#[derive(Clone)]
pub struct ExpConfig {
    pub scheme: Scheme,
    pub block_size: usize,
    pub stripes: usize,
    pub cross_gbps: f64,
    pub aggregated: bool,
    pub engine: Arc<dyn CodingEngine>,
    pub seed: u64,
    /// Fold measured (real) coding time into the virtual clock. On for the
    /// paper experiments; off for deterministic tests (same seed ⇒ same
    /// virtual latencies regardless of host load or thread counts).
    pub time_compute: bool,
    /// Decode-plan cache warm-up policy (`--plan-warmup`; experiment 7).
    pub plan_warmup: WarmupMode,
    /// Explicit per-cluster node counts (`--topology 9,9,8,8,…`) instead
    /// of the family's minimal uniform topology. Validated per family by
    /// [`custom_topology`].
    pub topology: Option<Vec<usize>>,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scheme: Scheme::S42,
            block_size: 256 * 1024,
            stripes: 4,
            cross_gbps: 1.0,
            aggregated: true,
            engine: Arc::new(NativeCoder),
            seed: 42,
            time_compute: true,
            plan_warmup: WarmupMode::Off,
            topology: None,
        }
    }
}

impl ExpConfig {
    /// Select the PJRT backend (requires `make artifacts`).
    pub fn with_pjrt(mut self) -> Result<Self> {
        self.engine = Arc::new(PjrtCoder::new(None)?);
        Ok(self)
    }
}

/// Build the per-family DSS: UniLRC on its native placement, baselines on
/// ECWide, each with exactly the clusters it needs (§6 Setup) — or on the
/// explicitly configured (possibly asymmetric) topology.
pub fn build_dss(fam: CodeFamily, cfg: &ExpConfig) -> Dss {
    let code = cfg.scheme.build(fam);
    let (strategy, topo) = strategy_and_topo(fam, &code);
    let topo = match &cfg.topology {
        Some(sizes) => custom_topology(fam, &code, sizes)
            .unwrap_or_else(|e| panic!("invalid --topology for {fam:?}: {e}")),
        None => topo,
    };
    Dss::new(
        code,
        strategy,
        topo,
        NetConfig::default().with_cross_gbps(cfg.cross_gbps),
        cfg.engine.clone(),
        DssConfig {
            block_size: cfg.block_size,
            aggregated: cfg.aggregated,
            time_compute: cfg.time_compute,
        },
    )
}

/// Parse a `--topology` / `[topology] clusters` spec (`"9,9,8,8"`) into
/// per-cluster node counts — the one grammar both the CLI and config
/// paths share.
pub fn parse_topology_spec(spec: &str) -> Result<Vec<usize>> {
    let sizes: Vec<usize> = spec
        .split(',')
        .map(|p| p.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("bad topology spec {spec:?} (want e.g. 9,9,8,8)"))?;
    anyhow::ensure!(
        !sizes.is_empty() && sizes.iter().all(|&n| n > 0),
        "topology needs positive cluster sizes"
    );
    Ok(sizes)
}

/// Validate explicit cluster sizes against **every** paper family of
/// `scheme` — the experiment drivers run every family, so a spec that any
/// family cannot place is rejected up front (clean error instead of a
/// panic deep inside `build_dss`).
pub fn validate_topology(scheme: Scheme, sizes: &[usize]) -> Result<()> {
    for fam in CodeFamily::paper_baselines() {
        let code = scheme.build(fam);
        custom_topology(fam, &code, sizes)?;
    }
    Ok(())
}

/// Validate explicit cluster sizes against a family's placement needs and
/// build the asymmetric topology.
pub fn custom_topology(
    fam: CodeFamily,
    code: &crate::codes::Code,
    sizes: &[usize],
) -> Result<Topology> {
    anyhow::ensure!(!sizes.is_empty(), "topology needs at least one cluster");
    let (_, min_topo) = strategy_and_topo(fam, code);
    anyhow::ensure!(
        sizes.len() >= min_topo.clusters(),
        "{} needs ≥ {} clusters, topology lists {}",
        code.name(),
        min_topo.clusters(),
        sizes.len()
    );
    // the minimal uniform topology allots biggest-chunk + 2 spare nodes
    let per_cluster_need = min_topo.cluster_size(0).saturating_sub(2);
    anyhow::ensure!(
        sizes.iter().all(|&s| s >= per_cluster_need),
        "every cluster needs ≥ {per_cluster_need} nodes for {} (rotation puts its \
         largest chunk in each cluster eventually)",
        code.name()
    );
    Ok(Topology::with_cluster_sizes(sizes))
}

/// Placement strategy + a topology sized to its largest per-cluster
/// chunk (plus spare nodes for reconstruction targets).
pub fn strategy_and_topo(
    fam: CodeFamily,
    code: &crate::codes::Code,
) -> (Box<dyn PlacementStrategy>, Topology) {
    match fam {
        CodeFamily::UniLrc => {
            let clusters = code.groups().len();
            let biggest = code.groups().iter().map(|g| g.members.len()).max().unwrap();
            (Box::new(UniLrcPlace), Topology::new(clusters, biggest + 2))
        }
        _ => {
            let chunks = EcWide::chunks(code);
            let biggest = chunks.iter().map(|c| c.len()).max().unwrap();
            (Box::new(EcWide), Topology::new(chunks.len(), biggest + 2))
        }
    }
}

/// One (family, value) result row.
#[derive(Debug, Clone)]
pub struct Row {
    pub family: CodeFamily,
    pub value: f64,
    pub unit: &'static str,
}

fn mib(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / (1 << 20) as f64
}

/// `mean` over possibly-empty measurement sets (0 instead of NaN).
fn mean_or_zero(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        mean(samples)
    }
}

/// Experiment 1 — normal-read throughput (Fig 10(a)), MiB/s.
pub fn exp1_normal_read(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let mut tputs = Vec::new();
        for s in 0..cfg.stripes {
            let r = dss.normal_read(s)?;
            tputs.push(mib(r.bytes, r.latency));
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 2 — degraded-read latency (Fig 10(b)), milliseconds.
pub fn exp2_degraded_read(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(1, &mut prng)?;
        let mut lats = Vec::new();
        for target in 0..dss.code.k() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.degraded_read(0, target)?;
            lats.push(r.latency * 1e3);
            dss.heal_node(node);
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&lats), unit: "ms" });
    }
    Ok(rows)
}

/// Experiment 2b — batched degraded-read burst, milliseconds: fail one
/// node, then request every one of its lost data blocks *at the same
/// instant*. The whole burst's repairs go through the proxy as one batched
/// event (`ProxyCtx::repair_node`), so the engine's worker pool overlaps
/// the per-stripe combines — the multi-stripe shape the §5 evaluation
/// measures.
pub fn exp2_degraded_burst(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let lost: Vec<_> = dss
            .metadata()
            .blocks_on_node(node)
            .into_iter()
            .filter(|&(_, b)| b < dss.code.k())
            .collect();
        anyhow::ensure!(!lost.is_empty(), "failed node hosts no data blocks");
        let r = dss.parallel_read(&lost)?;
        rows.push(Row { family: fam, value: r.latency * 1e3, unit: "ms" });
    }
    Ok(rows)
}

/// Experiment 3a — single-block recovery throughput (Fig 10(c)), MiB/s.
pub fn exp3_reconstruction(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(1, &mut prng)?;
        let mut tputs = Vec::new();
        for target in 0..dss.code.n() {
            let node = dss.metadata().node_of(0, target);
            dss.fail_node(node);
            let r = dss.reconstruct(0, target)?;
            tputs.push(mib(r.bytes, r.latency));
            dss.heal_node(node);
            dss.quiesce();
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 3b — full-node recovery throughput (Fig 10(d)), MiB/s.
pub fn exp3_node_recovery(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let r = dss.recover_node(node)?;
        rows.push(Row { family: fam, value: r.throughput_mib_s(), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 4 — reconstruction throughput vs cross-cluster bandwidth
/// (Fig 11(a)): (gbps, per-family MiB/s).
pub fn exp4_bandwidth(cfg: &ExpConfig, sweep: &[f64]) -> Result<Vec<(f64, Vec<Row>)>> {
    let mut out = Vec::new();
    for &gbps in sweep {
        let mut c = cfg.clone();
        c.cross_gbps = gbps;
        out.push((gbps, exp3_reconstruction(&c)?));
    }
    Ok(out)
}

/// Experiment 5 — decoding (pure compute) throughput (Fig 11(b)), MiB/s:
/// time the coding-library combine for a single-block repair, no network.
pub fn exp5_decode(cfg: &ExpConfig) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let code = cfg.scheme.build(fam);
        let data: Vec<Vec<u8>> = (0..code.k()).map(|_| prng.bytes(cfg.block_size)).collect();
        let drefs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parities = cfg.engine.encode(&code, &drefs)?;
        let stripe: Vec<&[u8]> =
            drefs.iter().copied().chain(parities.iter().map(|v| v.as_slice())).collect();
        let mut tputs = Vec::new();
        for target in 0..code.n() {
            let plan = code.repair_plan(target);
            let srcs: Vec<&[u8]> = plan.sources.iter().map(|&s| stripe[s]).collect();
            let t = std::time::Instant::now();
            let out = if plan.xor_only() {
                cfg.engine.fold(&srcs)?
            } else {
                cfg.engine.matmul(&[plan.coeffs.clone()], &srcs)?.pop().unwrap()
            };
            let dt = t.elapsed().as_secs_f64();
            anyhow::ensure!(out.as_slice() == stripe[target], "decode mismatch");
            crate::gf::pool::recycle(out);
            tputs.push(mib(cfg.block_size, dt));
        }
        rows.push(Row { family: fam, value: mean(&tputs), unit: "MiB/s" });
    }
    Ok(rows)
}

/// Experiment 6 — production-workload latency CDFs (Fig 12).
pub struct Exp6Result {
    pub family: CodeFamily,
    pub normal_mean_ms: f64,
    pub degraded_mean_ms: f64,
    pub normal_cdf: Vec<(f64, f64)>,
    pub degraded_cdf: Vec<(f64, f64)>,
}

pub fn exp6_production(
    cfg: &ExpConfig,
    objects: usize,
    requests: usize,
) -> Result<Vec<Exp6Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        let mut prng = Prng::new(cfg.seed);
        let mut dss = build_dss(fam, cfg);
        dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
        let wl = Workload::place_fit(&dss, WorkloadSpec::default(), objects, &mut prng);

        // normal reads
        let mut normal = Vec::new();
        for i in 0..requests {
            let obj = prng.gen_range(wl.objects.len());
            let _ = i;
            let r = wl.read_object(&mut dss, obj)?;
            normal.push(r.latency * 1e3);
            dss.quiesce();
        }

        // degrade one node, re-issue
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        let mut degraded = Vec::new();
        for _ in 0..requests {
            let obj = prng.gen_range(wl.objects.len());
            let r = wl.read_object(&mut dss, obj)?;
            degraded.push(r.latency * 1e3);
            dss.quiesce();
        }

        out.push(Exp6Result {
            family: fam,
            normal_mean_ms: mean(&normal),
            degraded_mean_ms: mean(&degraded),
            normal_cdf: cdf_points(&normal, 20),
            degraded_cdf: cdf_points(&degraded, 20),
        });
    }
    Ok(out)
}

/// Node-failure tolerance used in the reliability comparisons (Table 4):
/// the scheme's `f` for UniLRC/ALRC/ULRC; OLRC's larger distance bound
/// (`d = n − k − ⌈k/r⌉ + 2`, Theorem 2.3).
pub fn family_tolerance(scheme: Scheme, fam: CodeFamily) -> usize {
    match fam {
        CodeFamily::Olrc => {
            let code = scheme.build(CodeFamily::Olrc);
            let r = code.repair_plan(0).sources.len();
            code.n() - code.k() - code.k().div_ceil(r) + 1
        }
        _ => scheme.f,
    }
}

/// Experiment 7 (fault injection) configuration, on top of [`ExpConfig`].
#[derive(Debug, Clone)]
pub struct FaultSimConfig {
    /// Failure/repair clocks and horizon ([`FaultConfig`]).
    pub fault: FaultConfig,
    /// Co-resident tenants, each drawing its own object-size mix.
    pub tenants: usize,
    /// Objects placed per tenant.
    pub objects_per_tenant: usize,
    /// Objects read per tenant on each measured failure burst.
    pub reads_per_event: usize,
    /// Cap on events that trigger *measured* DSS operations (degraded-read
    /// bursts and batched recoveries). Occupancy statistics — degraded and
    /// unavailable time — always cover the whole trace, so long horizons
    /// stay cheap while the measured sample stays representative.
    pub measure_cap: usize,
}

impl Default for FaultSimConfig {
    fn default() -> Self {
        FaultSimConfig {
            fault: FaultConfig::default(),
            tenants: 3,
            objects_per_tenant: 8,
            reads_per_event: 2,
            measure_cap: 64,
        }
    }
}

/// Per-family summary of one fault-injection run.
#[derive(Debug, Clone)]
pub struct Exp7Result {
    pub family: CodeFamily,
    /// Fingerprint of the trace **and** every measured virtual latency —
    /// the determinism witness (same seed ⇒ same digest, any thread count).
    pub digest: u64,
    pub events: usize,
    pub node_failures: usize,
    pub cluster_failures: usize,
    /// Measured batched recovery events / blocks rebuilt across them.
    pub repair_events: usize,
    pub repaired_blocks: usize,
    pub mean_repair_ms: f64,
    pub cross_bytes: u64,
    /// Measured multi-tenant degraded-read bursts.
    pub degraded_reads: usize,
    pub mean_degraded_ms: f64,
    /// Hours with ≥ 1 failed block in any stripe / with some stripe
    /// unrecoverable, integrated over the whole trace.
    pub degraded_hours: f64,
    pub unavailable_hours: f64,
    /// Stripes that crossed an unrecoverable pattern at a repair event
    /// (data loss under the injected schedule; the virtual store restores
    /// ground truth on heal, modelling an out-of-band backup restore).
    pub data_loss_stripe_events: usize,
    /// Decode plans inserted by `--plan-warmup` (0 when off).
    pub prefetched_plans: usize,
    /// Fraction of time stripe 0 had ≥ 1 failed block, measured vs the
    /// closed-form birth–death steady state (`analysis::markov`).
    pub sim_degraded_frac: f64,
    pub markov_degraded_frac: f64,
    /// MTTDL through the injector's chain, from trace-estimated rates vs
    /// from the configured rates.
    pub mttdl_est_years: f64,
    pub mttdl_markov_years: f64,
}

/// Predicted erasure patterns of a fault trace: for every node that fails
/// (directly or via a cluster event) and every stripe, the blocks that
/// node hosts; for every correlated cluster event and stripe, the whole
/// cluster's blocks. Single-block patterns whose block repairs inside a
/// local group are dropped — that path XORs the group without consulting
/// the plan cache.
pub fn predicted_patterns(dss: &Dss, trace: &FaultTrace) -> Vec<Vec<usize>> {
    let mut patterns: Vec<Vec<usize>> = Vec::new();
    for node in trace.failing_nodes(&dss.topo) {
        patterns.extend(patterns_for_node(dss, node));
    }
    for cluster in trace.failing_clusters() {
        patterns.extend(patterns_for_cluster(dss, cluster));
    }
    normalize_patterns(dss, patterns)
}

/// Per-stripe erasure patterns a node's loss realizes (the blocks it
/// hosts, grouped by stripe).
fn patterns_for_node(dss: &Dss, node: usize) -> Vec<Vec<usize>> {
    let mut per_stripe: std::collections::BTreeMap<StripeId, Vec<usize>> = Default::default();
    for (stripe, block) in dss.metadata().blocks_on_node(node) {
        per_stripe.entry(stripe).or_default().push(block);
    }
    per_stripe.into_values().collect()
}

/// Per-stripe whole-cluster erasure patterns (the BlockMap's precomputed
/// per-cluster index, not an O(n) placement scan).
fn patterns_for_cluster(dss: &Dss, cluster: usize) -> Vec<Vec<usize>> {
    (0..dss.metadata().stripe_count())
        .map(|s| dss.metadata().blocks_in_cluster(s, cluster).to_vec())
        .collect()
}

/// Normalize predicted patterns: sort each, drop empties and single-block
/// patterns whose repair is an in-group XOR (that path never consults the
/// plan cache), dedup the set.
fn normalize_patterns(dss: &Dss, mut patterns: Vec<Vec<usize>>) -> Vec<Vec<usize>> {
    for p in &mut patterns {
        p.sort_unstable();
    }
    patterns.retain(|p| match p.as_slice() {
        [] => false,
        [single] => dss.code.group_of(*single).is_none(),
        _ => true,
    });
    patterns.sort();
    patterns.dedup();
    patterns
}

/// Online failure-history learner behind `--plan-warmup learned`: instead
/// of reading the fault trace ahead of time, it observes which nodes and
/// clusters *actually* went down during replay (the [`DownState`]
/// history) and predicts the erasure patterns a recurrence would realize
/// — real deployments see the same marginal nodes and racks fail
/// repeatedly, so warming their plans pays off on the next burst.
#[derive(Debug, Default)]
pub struct PatternPredictor {
    seen_nodes: std::collections::BTreeSet<usize>,
    seen_clusters: std::collections::BTreeSet<usize>,
}

impl PatternPredictor {
    pub fn new() -> PatternPredictor {
        PatternPredictor::default()
    }

    /// Nodes/clusters observed failing so far.
    pub fn observed(&self) -> (usize, usize) {
        (self.seen_nodes.len(), self.seen_clusters.len())
    }

    /// Record a failure burst; returns the erasure patterns *newly*
    /// predicted by this observation (first sighting of a node predicts
    /// its per-stripe block patterns; first sighting of a correlated
    /// cluster event predicts whole-cluster patterns). Repeat sightings
    /// return nothing — their patterns are already warm.
    pub fn observe(
        &mut self,
        dss: &Dss,
        failed_nodes: &[usize],
        failed_clusters: &[usize],
    ) -> Vec<Vec<usize>> {
        let mut patterns: Vec<Vec<usize>> = Vec::new();
        for &node in failed_nodes {
            if self.seen_nodes.insert(node) {
                patterns.extend(patterns_for_node(dss, node));
            }
        }
        for &cluster in failed_clusters {
            if self.seen_clusters.insert(cluster) {
                patterns.extend(patterns_for_cluster(dss, cluster));
            }
        }
        normalize_patterns(dss, patterns)
    }
}

/// Experiment 7 — deterministic fault injection: replay a seeded failure
/// schedule ([`FaultTrace`]) against the virtual-time DSS for each code
/// family, measuring degraded multi-tenant reads at failure bursts,
/// batched recovery at repair events, cross-cluster repair traffic, and
/// data-(un)availability windows; closed-form reliability predictions
/// ride along for the differential check.
///
/// Fully deterministic by construction: compute timing never folds into
/// the virtual clock (regardless of `cfg.time_compute`), so the digest is
/// a pure function of `(scheme, family, seed, config)` — identical across
/// runs, kernels, and worker-thread counts.
pub fn exp7_faults(cfg: &ExpConfig, fcfg: &FaultSimConfig) -> Result<Vec<Exp7Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        out.push(exp7_family(fam, cfg, fcfg)?);
    }
    Ok(out)
}

/// Piecewise-constant occupancy integrals accumulated between fault
/// events (and over the tail to the horizon).
#[derive(Default)]
struct Occupancy {
    /// Hours with ≥ 1 failed block in any stripe.
    degraded_hours: f64,
    /// Hours with some stripe's pattern unrecoverable.
    unavailable_hours: f64,
    /// Hours with ≥ 1 failed block in stripe 0 (the Markov comparator).
    s0_degraded_hours: f64,
    /// Σ (down nodes × hours) — the denominator of the μ̂ rate estimate.
    node_down_hours: f64,
}

impl Occupancy {
    fn accrue(&mut self, dss: &Dss, state: &DownState, dt: f64) {
        if dt <= 0.0 || state.down_count() == 0 {
            return;
        }
        let (degraded, unavailable) = dss.availability();
        if degraded {
            self.degraded_hours += dt;
        }
        if unavailable {
            self.unavailable_hours += dt;
        }
        if !dss.failed_blocks(0).is_empty() {
            self.s0_degraded_hours += dt;
        }
        self.node_down_hours += state.down_count() as f64 * dt;
    }
}

fn exp7_family(fam: CodeFamily, cfg: &ExpConfig, fcfg: &FaultSimConfig) -> Result<Exp7Result> {
    let mut det = cfg.clone();
    det.time_compute = false;
    let mut dss = build_dss(fam, &det);
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
    let tenants = Workload::place_tenants(&dss, fcfg.tenants, fcfg.objects_per_tenant, &mut prng);

    let trace = FaultTrace::generate(&dss.topo, &fcfg.fault, cfg.seed);
    let mut digest = digest_mix(crate::sim::faults::DIGEST_SEED, trace.digest());

    let mut prefetched_plans = match cfg.plan_warmup {
        WarmupMode::Trace => {
            let patterns = predicted_patterns(&dss, &trace);
            dss.prefetch_plans(&patterns)
        }
        WarmupMode::Off | WarmupMode::Learned => 0,
    };
    let mut predictor =
        (cfg.plan_warmup == WarmupMode::Learned).then(PatternPredictor::new);

    let horizon = fcfg.fault.horizon_hours;
    let n_nodes = dss.topo.total_nodes();
    let mut state = DownState::new(&dss.topo);
    let mut t_prev = 0.0f64;
    let mut occ = Occupancy::default();
    let (mut node_failures, mut cluster_failures) = (0usize, 0usize);
    let (mut fail_transitions, mut repair_transitions) = (0usize, 0usize);
    let (mut repair_events, mut repaired_blocks) = (0usize, 0usize);
    let (mut repair_ms, mut degraded_ms) = (Vec::new(), Vec::new());
    let mut cross_bytes = 0u64;
    let mut data_loss_stripe_events = 0usize;
    let mut measured = 0usize;

    for (ei, ev) in trace.events.iter().enumerate() {
        // occupancy since the previous event, under the pre-event state
        occ.accrue(&dss, &state, ev.at_hours - t_prev);
        t_prev = ev.at_hours;

        // ------------------------------------------- apply the event
        match ev.kind {
            FaultKind::NodeFail(_) => node_failures += 1,
            FaultKind::ClusterFail(_) => cluster_failures += 1,
            _ => {}
        }
        let mut failed_now = Vec::new();
        let mut healed_now = Vec::new();
        for (node, down) in state.apply(ev.kind) {
            if down {
                dss.fail_node(node);
                fail_transitions += 1;
                failed_now.push(node);
            } else {
                repair_transitions += 1;
                healed_now.push(node);
            }
        }

        // --------- learned warm-up: observe the burst, prefetch its
        // recurrence patterns (virtual-time-invisible, so the digest is
        // identical warm or cold — asserted by tests/faults.rs)
        if let Some(pred) = predictor.as_mut() {
            let clusters_now: Vec<usize> = match ev.kind {
                FaultKind::ClusterFail(c) => vec![c],
                _ => Vec::new(),
            };
            if !failed_now.is_empty() || !clusters_now.is_empty() {
                let patterns = pred.observe(&dss, &failed_now, &clusters_now);
                if !patterns.is_empty() {
                    prefetched_plans += dss.prefetch_plans(&patterns);
                }
            }
        }

        // ------------- failure burst: multi-tenant degraded-read fan-out
        if !failed_now.is_empty() && measured < fcfg.measure_cap {
            let (_, unavail) = dss.availability();
            if !unavail {
                let mut ep = Prng::new(cfg.seed ^ (0xE7E7_0000 + ei as u64));
                let mut blocks: Vec<(StripeId, usize)> = Vec::new();
                for wl in &tenants {
                    let mut cand: Vec<usize> = failed_now
                        .iter()
                        .flat_map(|&node| wl.objects_touching(&dss, node))
                        .collect();
                    cand.sort_unstable();
                    cand.dedup();
                    for _ in 0..fcfg.reads_per_event.min(cand.len()) {
                        let obj = cand.swap_remove(ep.gen_range(cand.len()));
                        blocks.extend(wl.objects[obj].iter().copied());
                    }
                }
                if !blocks.is_empty() {
                    let r = dss.parallel_read(&blocks)?;
                    degraded_ms.push(r.latency * 1e3);
                    digest = digest_mix(digest, r.latency.to_bits());
                    dss.quiesce();
                    measured += 1;
                }
            }
        }

        // -------- repair burst: batched recovery of the returning nodes
        if !healed_now.is_empty() {
            let mut lost: Vec<(StripeId, usize)> = healed_now
                .iter()
                .flat_map(|&node| dss.metadata().blocks_on_node(node))
                .collect();
            lost.sort_unstable();
            let mut lost_stripes = std::collections::BTreeSet::new();
            lost.retain(|&(stripe, _)| {
                if dss.stripe_recoverable(stripe) {
                    true
                } else {
                    lost_stripes.insert(stripe);
                    false
                }
            });
            data_loss_stripe_events += lost_stripes.len();
            if !lost.is_empty() && measured < fcfg.measure_cap {
                let r = dss.recover_blocks(&lost)?;
                repair_events += 1;
                repaired_blocks += r.blocks;
                cross_bytes += r.cross_bytes;
                repair_ms.push(r.seconds * 1e3);
                digest = digest_mix(digest, r.seconds.to_bits());
                digest = digest_mix(digest, r.cross_bytes);
                dss.quiesce();
                measured += 1;
            }
            for &node in &healed_now {
                dss.heal_node(node);
            }
        }
    }
    // tail occupancy from the last event to the horizon
    occ.accrue(&dss, &state, horizon - t_prev);

    // ------------------- closed-form comparison (analysis::markov chain)
    let n = dss.code.n();
    let f_tol = family_tolerance(cfg.scheme, fam);
    let node_clocks_on = fcfg.fault.node_mttf_hours > 0.0 && fcfg.fault.node_mttr_hours > 0.0;
    let (markov_degraded_frac, mttdl_markov_years) = if node_clocks_on {
        let lambda = 1.0 / fcfg.fault.node_mttf_hours;
        let mu = 1.0 / fcfg.fault.node_mttr_hours;
        (
            markov::degraded_fraction(n, lambda, mu),
            markov::mttdl_injected_years(n, f_tol, lambda, mu),
        )
    } else {
        (0.0, f64::INFINITY)
    };
    // rate estimates from the trace (effective per-node transitions)
    let up_hours = n_nodes as f64 * horizon - occ.node_down_hours;
    let have_rates = fail_transitions > 0 && repair_transitions > 0 && occ.node_down_hours > 0.0;
    let mttdl_est_years = if have_rates {
        let lambda_hat = fail_transitions as f64 / up_hours;
        let mu_hat = repair_transitions as f64 / occ.node_down_hours;
        markov::mttdl_injected_years(n, f_tol, lambda_hat, mu_hat)
    } else {
        f64::INFINITY
    };

    Ok(Exp7Result {
        family: fam,
        digest,
        events: trace.events.len(),
        node_failures,
        cluster_failures,
        repair_events,
        repaired_blocks,
        mean_repair_ms: mean_or_zero(&repair_ms),
        cross_bytes,
        degraded_reads: degraded_ms.len(),
        mean_degraded_ms: mean_or_zero(&degraded_ms),
        degraded_hours: occ.degraded_hours,
        unavailable_hours: occ.unavailable_hours,
        data_loss_stripe_events,
        prefetched_plans,
        sim_degraded_frac: occ.s0_degraded_hours / horizon,
        markov_degraded_frac,
        mttdl_est_years,
        mttdl_markov_years,
    })
}

// --------------------------------------------------------------------------
// Experiment 8 — elastic topology: scale-out and drain scenarios
// --------------------------------------------------------------------------

/// Experiment 8 scenario knobs (CLI `--add-nodes` etc., config
/// `[elastic]`).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// AddNode events, round-robin over existing clusters.
    pub add_nodes: usize,
    /// DrainNode events (the most-loaded live node each time).
    pub drain_nodes: usize,
    /// AddCluster events (whole-cluster scale-out + rebalance).
    pub add_clusters: usize,
    /// Nodes per added cluster (0 = match the largest existing cluster).
    pub cluster_nodes: usize,
    /// Post-scale fault replay horizon in hours (0 = skip): regenerates
    /// fail/repair clocks on the *mutated* topology — fresh nodes tick,
    /// dead nodes do not — and runs one batched recovery on it.
    pub fault_horizon_hours: f64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            add_nodes: 2,
            drain_nodes: 2,
            add_clusters: 1,
            cluster_nodes: 0,
            fault_horizon_hours: 400.0,
        }
    }
}

/// Per-family summary of one elastic-topology run.
#[derive(Debug, Clone)]
pub struct Exp8Result {
    pub family: CodeFamily,
    /// Fingerprint of every migration plan size, byte meter and virtual
    /// latency — the determinism witness (same seed ⇒ same digest).
    pub digest: u64,
    /// Topology events applied.
    pub events: usize,
    /// Blocks moved across all migrations.
    pub moves: usize,
    /// Moves rebuilt through the batched repair pipeline (dead/failed
    /// sources).
    pub repaired_moves: usize,
    pub migrated_bytes: usize,
    /// Cross-cluster migration traffic (gateway-metered), the per-family
    /// comparison the rebalance bench tracks.
    pub cross_migration_bytes: u64,
    /// Σ virtual seconds of all migration waves.
    pub migration_seconds: f64,
    /// (stripe, cluster) whole-cluster-loss decode checks passed after
    /// every event.
    pub invariant_checks: usize,
    /// Events in the post-scale fault trace (0 when skipped).
    pub post_scale_fault_events: usize,
    pub final_clusters: usize,
    pub final_live_nodes: usize,
    /// Closed-form degraded-exposure cross-check: probability that ≥ 1
    /// node-failure clock fires somewhere during the total migration
    /// window ([`markov::migration_exposure`]).
    pub exposure_prob: f64,
    /// Per-event timing rows `(event, wall_ms, virtual_seconds, moves)` —
    /// the wall/virtual split per topology event, the comparable baseline
    /// for exp9's recovery-replay timings. Not part of the digest.
    pub event_timings: Vec<(TopologyEvent, f64, f64, usize)>,
}

/// Most-loaded active, non-failed node (ties break to the lowest id) —
/// the deterministic drain victim.
fn most_loaded_live_node(dss: &Dss) -> Option<usize> {
    (0..dss.topo.total_nodes())
        .filter(|&n| dss.topo.is_active(n) && !dss.failed_nodes().contains(&n))
        .max_by_key(|&n| (dss.metadata().block_map().node_load(n), std::cmp::Reverse(n)))
}

/// Experiment 8 — elastic topology: replay a deterministic scale-out /
/// drain scenario against every code family, with every migration planned
/// by the scheduler ([`crate::coordinator::migrate`]) and executed as
/// batched coding + transfer waves on the virtual clock. After each event
/// the one-cluster-failure invariant is re-proven from the live
/// [`crate::coordinator::BlockMap`]; cross-cluster migration bytes are
/// metered per family. Compute timing is forced off the virtual clock, so
/// the digest is a pure function of `(scheme, family, seed, config)`.
pub fn exp8_elastic(cfg: &ExpConfig, ecfg: &ElasticConfig) -> Result<Vec<Exp8Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        out.push(exp8_family(fam, cfg, ecfg)?);
    }
    Ok(out)
}

fn exp8_family(fam: CodeFamily, cfg: &ExpConfig, ecfg: &ElasticConfig) -> Result<Exp8Result> {
    let mut det = cfg.clone();
    det.time_compute = false;
    let mut dss = build_dss(fam, &det);
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng)?;

    let mut digest = digest_mix(crate::sim::faults::DIGEST_SEED, 0xE8);
    let mut reports: Vec<MigrationReport> = Vec::new();
    let mut invariant_checks = 0usize;

    let mut run_event = |dss: &mut Dss, ev: TopologyEvent| -> Result<MigrationReport> {
        dss.quiesce(); // per-event meters: seconds/cross_bytes start at zero
        let r = dss.apply_topology_event(ev)?;
        // re-prove one-cluster-failure tolerance from the live block map
        // (the precomputed per-cluster index, not an O(n) placement scan)
        for s in 0..dss.metadata().stripe_count() {
            for c in 0..dss.topo.clusters() {
                let blocks = dss.metadata().blocks_in_cluster(s, c);
                if blocks.is_empty() {
                    continue;
                }
                anyhow::ensure!(
                    dss.code.decode_plan_cached(blocks).is_some(),
                    "{fam:?}: stripe {s} would not survive losing cluster {c} after {ev:?}"
                );
                invariant_checks += 1;
            }
        }
        Ok(r)
    };

    for i in 0..ecfg.add_nodes {
        let cluster = i % dss.topo.clusters();
        reports.push(run_event(&mut dss, TopologyEvent::AddNode { cluster })?);
    }
    for _ in 0..ecfg.drain_nodes {
        let node = most_loaded_live_node(&dss)
            .ok_or_else(|| anyhow::anyhow!("no live node left to drain"))?;
        reports.push(run_event(&mut dss, TopologyEvent::DrainNode { node })?);
    }
    for _ in 0..ecfg.add_clusters {
        let nodes = if ecfg.cluster_nodes > 0 {
            ecfg.cluster_nodes
        } else {
            dss.topo.max_cluster_size()
        };
        reports.push(run_event(&mut dss, TopologyEvent::AddCluster { nodes })?);
    }
    if ecfg.drain_nodes > 0 {
        // one post-scale drain: proves drains still plan correctly on the
        // grown, asymmetric topology
        let node = most_loaded_live_node(&dss)
            .ok_or_else(|| anyhow::anyhow!("no live node left to drain"))?;
        reports.push(run_event(&mut dss, TopologyEvent::DrainNode { node })?);
    }

    let event_timings: Vec<(TopologyEvent, f64, f64, usize)> =
        reports.iter().map(|r| (r.event, r.wall_ms, r.seconds, r.moves)).collect();

    let (mut moves, mut repaired, mut bytes) = (0usize, 0usize, 0usize);
    let (mut cross, mut seconds) = (0u64, 0.0f64);
    for r in &reports {
        moves += r.moves;
        repaired += r.repaired_moves;
        bytes += r.bytes_moved;
        cross += r.cross_bytes;
        seconds += r.seconds;
        digest = digest_mix(digest, r.moves as u64);
        digest = digest_mix(digest, r.repaired_moves as u64);
        digest = digest_mix(digest, r.cross_bytes);
        digest = digest_mix(digest, r.seconds.to_bits());
    }

    // a normal read over the migrated map still serves (and is timed)
    dss.quiesce();
    let read = dss.normal_read(0)?;
    digest = digest_mix(digest, read.latency.to_bits());

    // post-scale fault replay: clocks regenerate on the mutated topology
    let fault =
        FaultConfig { horizon_hours: ecfg.fault_horizon_hours, ..FaultConfig::accelerated() };
    let mut post_scale_fault_events = 0usize;
    if ecfg.fault_horizon_hours > 0.0 {
        let trace = FaultTrace::generate(&dss.topo, &fault, cfg.seed ^ 0xE8E8);
        post_scale_fault_events = trace.events.len();
        digest = digest_mix(digest, trace.digest());
        // one batched whole-node recovery on the migrated layout
        let victim = trace.events.iter().find_map(|e| match e.kind {
            FaultKind::NodeFail(n)
                if !dss.metadata().blocks_on_node(n).is_empty()
                    && dss.topo.is_live(n) =>
            {
                Some(n)
            }
            _ => None,
        });
        if let Some(n) = victim {
            dss.quiesce();
            dss.fail_node(n);
            let r = dss.recover_nodes(&[n])?;
            digest = digest_mix(digest, r.seconds.to_bits());
            digest = digest_mix(digest, r.cross_bytes);
            dss.heal_node(n);
        }
    }

    let lambda = if fault.node_mttf_hours > 0.0 { 1.0 / fault.node_mttf_hours } else { 0.0 };
    let exposure_prob =
        markov::migration_exposure(dss.topo.live_nodes().len(), lambda, seconds / 3600.0);

    Ok(Exp8Result {
        family: fam,
        digest,
        events: reports.len(),
        moves,
        repaired_moves: repaired,
        migrated_bytes: bytes,
        cross_migration_bytes: cross,
        migration_seconds: seconds,
        invariant_checks,
        post_scale_fault_events,
        final_clusters: dss.topo.clusters(),
        final_live_nodes: dss.topo.live_nodes().len(),
        exposure_prob,
        event_timings,
    })
}

// --------------------------------------------------------------------------
// Experiment 9 — durable coordinator: crash-restart recovery sweep
// --------------------------------------------------------------------------

/// Experiment 9 scenario knobs (CLI `--wal-sync-every` etc., config
/// `[durability]`).
#[derive(Debug, Clone)]
pub struct DurabilitySimConfig {
    /// fsync once per this many committed WAL groups (group commit;
    /// `--wal-sync-every` / `UNILRC_WAL_SYNC_EVERY`).
    pub wal_sync_every: usize,
    /// Snapshot cadence in committed ops for the snapshot-cadence
    /// verification run. The crash sweep itself pins snapshots off so a
    /// single WAL segment holds every crash position.
    pub snapshot_every: usize,
    /// AddNode events in the scale-out window.
    pub add_nodes: usize,
    /// DrainNode events.
    pub drain_nodes: usize,
    /// AddCluster events.
    pub add_clusters: usize,
    /// Extra fail → batched-recover → heal pairs appended after the scale
    /// window (the fault-replay tail).
    pub fault_ops: usize,
    /// Cap on crash positions tested per family (0 = every position).
    /// When sampling, the stride is forced odd so both record boundaries
    /// and mid-record (torn-tail) positions are exercised, and the tested
    /// count is reported next to the total — no silent caps.
    pub crash_cap: usize,
}

impl Default for DurabilitySimConfig {
    fn default() -> Self {
        DurabilitySimConfig {
            wal_sync_every: 8,
            snapshot_every: 4,
            add_nodes: 2,
            drain_nodes: 1,
            add_clusters: 1,
            fault_ops: 1,
            crash_cap: 64,
        }
    }
}

/// One deterministic driver operation of the exp9 scenario. Each op
/// commits exactly **one** WAL unit (a standalone record or one event
/// group), which is what lets a recovered run resume the op list from
/// [`crate::coordinator::Recovered::committed_ops`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DurOp {
    /// Ingest one stripe; data regenerated from `seed ^ op-index`, so a
    /// re-executed ingest produces byte-identical blocks.
    Ingest,
    /// AddNode, round-robin over open clusters.
    AddNode,
    /// Fail the lowest-id live, loaded, not-yet-failed node.
    Fail,
    /// Drain the most-loaded live node.
    Drain,
    /// Batched-recover then heal the lowest failed node.
    Heal,
    /// AddCluster sized to the largest existing cluster.
    AddCluster,
}

/// The scenario's op list: ingest, scale out, a failure, drains under an
/// outstanding failure, heal, whole-cluster scale-out, then the
/// fault-replay tail.
fn exp9_ops(cfg: &ExpConfig, dcfg: &DurabilitySimConfig) -> Vec<DurOp> {
    let mut ops = Vec::new();
    for _ in 0..cfg.stripes {
        ops.push(DurOp::Ingest);
    }
    for _ in 0..dcfg.add_nodes {
        ops.push(DurOp::AddNode);
    }
    ops.push(DurOp::Fail);
    for _ in 0..dcfg.drain_nodes {
        ops.push(DurOp::Drain);
    }
    ops.push(DurOp::Heal);
    for _ in 0..dcfg.add_clusters {
        ops.push(DurOp::AddCluster);
    }
    for _ in 0..dcfg.fault_ops {
        ops.push(DurOp::Fail);
        ops.push(DurOp::Heal);
    }
    ops
}

/// Execute one driver op. Every parameter is a pure function of the
/// current coordinator state plus the op index — a recovered run
/// re-executing the tail of the op list therefore reproduces the oracle
/// exactly (the property the digest comparison proves).
fn exp9_apply_op(dss: &mut Dss, op: DurOp, op_index: usize, cfg: &ExpConfig) -> Result<()> {
    match op {
        DurOp::Ingest => {
            let mut p = Prng::new(cfg.seed ^ (0xD9D9_0000 + op_index as u64));
            let data: Vec<Vec<u8>> =
                (0..dss.code.k()).map(|_| p.bytes(cfg.block_size)).collect();
            dss.ingest_stripe(data)?;
        }
        DurOp::AddNode => {
            let clusters = dss.topo.clusters();
            let cluster = (0..clusters)
                .map(|i| (op_index + i) % clusters)
                .find(|&c| !dss.topo.is_retired(c))
                .ok_or_else(|| anyhow::anyhow!("no open cluster to grow"))?;
            dss.apply_topology_event(TopologyEvent::AddNode { cluster })?;
        }
        DurOp::Fail => {
            let victim = (0..dss.topo.total_nodes())
                .find(|&n| {
                    dss.topo.is_live(n)
                        && !dss.failed_nodes().contains(&n)
                        && !dss.metadata().blocks_on_node(n).is_empty()
                })
                .ok_or_else(|| anyhow::anyhow!("no live loaded node to fail"))?;
            dss.fail_node(victim);
        }
        DurOp::Drain => {
            let node = most_loaded_live_node(dss)
                .ok_or_else(|| anyhow::anyhow!("no live node left to drain"))?;
            dss.apply_topology_event(TopologyEvent::DrainNode { node })?;
        }
        DurOp::Heal => {
            let victim = dss
                .failed_nodes()
                .iter()
                .copied()
                .min()
                .ok_or_else(|| anyhow::anyhow!("heal op with empty failure set"))?;
            // Repairs rebuild bytes but never move blocks in the map, so
            // the only durable mutation here is the heal itself.
            dss.recover_nodes(&[victim])?;
            dss.heal_node(victim);
        }
        DurOp::AddCluster => {
            let nodes = dss.topo.max_cluster_size();
            dss.apply_topology_event(TopologyEvent::AddCluster { nodes })?;
        }
    }
    Ok(())
}

/// Per-family summary of one crash-restart recovery sweep.
#[derive(Debug, Clone)]
pub struct Exp9Result {
    pub family: CodeFamily,
    /// Final-state digest of the never-crashed oracle run; every crash
    /// point's recovered + re-executed state must digest identically.
    pub oracle_digest: u64,
    /// Driver operations in the scenario (each = one committed WAL unit).
    pub ops: usize,
    pub wal_records: u64,
    pub wal_bytes: u64,
    /// Distinct crash positions (every record boundary plus a mid-record
    /// point inside every record) in the oracle WAL…
    pub crash_points_total: usize,
    /// …and how many were actually tested (`crash_cap` sampling).
    pub crash_points_tested: usize,
    /// Crash points whose recovered state digested equal to the oracle
    /// (must equal `crash_points_tested`).
    pub digest_matches: usize,
    /// Crash points that recovered with a mid-flight topology event
    /// surfaced for re-planning.
    pub pending_replans: usize,
    /// Crash points whose final segment ended in a torn record.
    pub torn_tails: usize,
    /// (stripe, cluster) decode-plan gates passed across all crash points.
    pub decode_checks: usize,
    /// Rotating byte-exact reconstructions performed (one per crash point).
    pub reconstructed_blocks: usize,
    /// Mean wall-clock cost of `recover()` per crash point…
    pub mean_recover_ms: f64,
    /// …and of re-executing the op tail on the restored coordinator
    /// (compare against exp8's per-event `wall_ms` rows).
    pub mean_reexec_ms: f64,
    /// Snapshot-cadence verification run: manifests written, and whether
    /// its recovery digested equal to the oracle.
    pub snapshot_run_snapshots: usize,
    pub snapshot_digest_match: bool,
}

/// Experiment 9 — durable coordinator: run a deterministic scale-out +
/// drain + fault-replay scenario with the WAL enabled, then kill the
/// coordinator at every distinct WAL position (each record boundary and a
/// point inside every record), recover from the surviving manifest + log,
/// re-execute the uncommitted op tail, and prove the recovered block map
/// byte-identical to the never-crashed oracle (FNV digest, exp7/exp8
/// discipline). Every recovered map also passes the erasure-matrix gate:
/// all stripes survive any single-cluster loss, and a rotating block is
/// byte-exactly reconstructed. A second run with periodic snapshots +
/// log truncation proves recovery across manifest rotation and GC.
pub fn exp9_durability(cfg: &ExpConfig, dcfg: &DurabilitySimConfig) -> Result<Vec<Exp9Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        out.push(exp9_family(fam, cfg, dcfg)?);
    }
    Ok(out)
}

fn exp9_scratch_dir(fam: CodeFamily, seed: u64, tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("unilrc-exp9-{}-{fam:?}-{seed}-{tag}", std::process::id()))
}

fn exp9_family(fam: CodeFamily, cfg: &ExpConfig, dcfg: &DurabilitySimConfig) -> Result<Exp9Result> {
    let mut det = cfg.clone();
    det.time_compute = false;
    let ops = exp9_ops(&det, dcfg);

    // ----------------- oracle: never crashes, periodic snapshots pinned
    // off so a single WAL segment holds every crash position
    let oracle_dir = exp9_scratch_dir(fam, det.seed, "oracle");
    let _ = std::fs::remove_dir_all(&oracle_dir);
    let mut dss = build_dss(fam, &det);
    dss.enable_durability(
        &oracle_dir,
        DurabilityOptions { sync_every: dcfg.wal_sync_every, snapshot_every: usize::MAX },
    )?;
    for (i, &op) in ops.iter().enumerate() {
        exp9_apply_op(&mut dss, op, i, &det)?;
    }
    let oracle_digest = dss.capture_state().digest();
    let blocks = dss.export_blocks();
    let journal = dss.journal().expect("durability enabled above");
    let (wal_records, wal_bytes) = (journal.wal_records(), journal.wal_bytes());
    anyhow::ensure!(
        journal.committed_ops() == ops.len() as u64,
        "{fam:?}: every driver op must commit exactly one WAL unit ({} != {})",
        journal.committed_ops(),
        ops.len()
    );
    drop(dss);

    // ------------------------------------------ enumerate crash positions
    let segments = list_segments(&oracle_dir)?;
    anyhow::ensure!(segments.len() == 1, "oracle journal must hold exactly one segment");
    let wal_path = segments[0].1.clone();
    let wal_img = std::fs::read(&wal_path)?;
    let (records, end) = scan_segment(&wal_img);
    anyhow::ensure!(end == ScanEnd::Clean, "oracle WAL must scan clean, got {end:?}");
    anyhow::ensure!(records.len() as u64 == wal_records, "oracle WAL record count mismatch");
    // Even indices are record boundaries, odd indices mid-record points.
    let mut positions: Vec<usize> = Vec::with_capacity(records.len() * 2 + 1);
    for (i, r) in records.iter().enumerate() {
        let next = records.get(i + 1).map_or(wal_img.len(), |n| n.offset);
        positions.push(r.offset);
        positions.push(r.offset + (next - r.offset) / 2);
    }
    positions.push(wal_img.len());
    let total = positions.len();
    let tested_idx: Vec<usize> = if dcfg.crash_cap > 0 && total > dcfg.crash_cap {
        let mut step = total.div_ceil(dcfg.crash_cap);
        if step % 2 == 0 {
            step += 1; // odd stride: sample boundaries *and* torn tails
        }
        let mut idx: Vec<usize> = (0..total).step_by(step).collect();
        if idx.last() != Some(&(total - 1)) {
            idx.push(total - 1);
        }
        idx
    } else {
        (0..total).collect()
    };

    // ----------------------------------------------------- the crash sweep
    let store = ManifestStore::new(&oracle_dir);
    let crash_dir = exp9_scratch_dir(fam, det.seed, "crash");
    let (mut digest_matches, mut pending_replans, mut torn_tails) = (0usize, 0usize, 0usize);
    let (mut decode_checks, mut reconstructed) = (0usize, 0usize);
    let (mut recover_ms, mut reexec_ms) = (Vec::new(), Vec::new());

    for (pi, &idx) in tested_idx.iter().enumerate() {
        let cut = positions[idx];
        let _ = std::fs::remove_dir_all(&crash_dir);
        std::fs::create_dir_all(&crash_dir)?;
        std::fs::copy(store.current_path(), crash_dir.join(MANIFEST_CURRENT))?;
        if store.prev_path().exists() {
            std::fs::copy(store.prev_path(), crash_dir.join(MANIFEST_PREV))?;
        }
        std::fs::write(
            crash_dir.join(wal_path.file_name().expect("segment file name")),
            &wal_img[..cut],
        )?;

        let t_rec = std::time::Instant::now();
        let rec = recover(&crash_dir).map_err(|e| {
            anyhow::anyhow!("{fam:?}: recovery at crash position {cut} failed: {e}")
        })?;
        recover_ms.push(t_rec.elapsed().as_secs_f64() * 1e3);
        torn_tails += rec.torn_tail as usize;
        pending_replans += rec.pending_event.is_some() as usize;

        let code = det.scheme.build(fam);
        let (strategy, _) = strategy_and_topo(fam, &code);
        let mut rdss = Dss::restore(
            code,
            strategy,
            &rec.state,
            blocks.clone(),
            NetConfig::default().with_cross_gbps(det.cross_gbps),
            det.engine.clone(),
            DssConfig {
                block_size: det.block_size,
                aggregated: det.aggregated,
                time_compute: false,
            },
        )?;

        let resume = rec.committed_ops as usize;
        anyhow::ensure!(
            resume <= ops.len(),
            "{fam:?}: recovered {resume} committed ops, scenario has only {}",
            ops.len()
        );
        let t_re = std::time::Instant::now();
        for (i, &op) in ops.iter().enumerate().skip(resume) {
            exp9_apply_op(&mut rdss, op, i, &det)?;
        }
        reexec_ms.push(t_re.elapsed().as_secs_f64() * 1e3);

        let got = rdss.capture_state().digest();
        anyhow::ensure!(
            got == oracle_digest,
            "{fam:?}: crash at WAL byte {cut} diverged: {got:#x} != oracle {oracle_digest:#x}"
        );
        digest_matches += 1;

        // erasure-matrix gate: every stripe survives any one-cluster loss…
        for s in 0..rdss.metadata().stripe_count() {
            for c in 0..rdss.topo.clusters() {
                let in_cluster = rdss.metadata().blocks_in_cluster(s, c);
                if in_cluster.is_empty() {
                    continue;
                }
                anyhow::ensure!(
                    rdss.code.decode_plan_cached(in_cluster).is_some(),
                    "{fam:?}: stripe {s} undecodable after losing cluster {c} (crash at {cut})"
                );
                decode_checks += 1;
            }
        }
        // …and one rotating byte-exact reconstruction proves real decode
        let stripes = rdss.metadata().stripe_count();
        if stripes > 0 {
            let s = pi % stripes;
            let b = pi % rdss.code.n();
            let node = rdss.metadata().node_of(s, b);
            rdss.fail_node(node);
            rdss.reconstruct(s, b)?;
            rdss.heal_node(node);
            reconstructed += 1;
        }
    }
    let _ = std::fs::remove_dir_all(&crash_dir);

    // -------------- snapshot-cadence verification run (rotation + GC on)
    let snap_dir = exp9_scratch_dir(fam, det.seed, "snap");
    let _ = std::fs::remove_dir_all(&snap_dir);
    let mut sdss = build_dss(fam, &det);
    sdss.enable_durability(
        &snap_dir,
        DurabilityOptions {
            sync_every: dcfg.wal_sync_every,
            snapshot_every: dcfg.snapshot_every.max(1),
        },
    )?;
    for (i, &op) in ops.iter().enumerate() {
        exp9_apply_op(&mut sdss, op, i, &det)?;
    }
    let snapshot_run_snapshots = sdss.journal().expect("durability enabled above").snapshots();
    anyhow::ensure!(
        sdss.capture_state().digest() == oracle_digest,
        "{fam:?}: snapshot-cadence run diverged from the oracle"
    );
    drop(sdss);
    let rec = recover(&snap_dir)
        .map_err(|e| anyhow::anyhow!("{fam:?}: snapshot-run recovery failed: {e}"))?;
    anyhow::ensure!(
        rec.committed_ops == ops.len() as u64,
        "{fam:?}: snapshot-run recovery lost committed ops"
    );
    let snapshot_digest_match = rec.state.digest() == oracle_digest;
    anyhow::ensure!(snapshot_digest_match, "{fam:?}: snapshot-run recovery diverged");
    let _ = std::fs::remove_dir_all(&snap_dir);
    let _ = std::fs::remove_dir_all(&oracle_dir);

    Ok(Exp9Result {
        family: fam,
        oracle_digest,
        ops: ops.len(),
        wal_records,
        wal_bytes,
        crash_points_total: total,
        crash_points_tested: tested_idx.len(),
        digest_matches,
        pending_replans,
        torn_tails,
        decode_checks,
        reconstructed_blocks: reconstructed,
        mean_recover_ms: mean_or_zero(&recover_ms),
        mean_reexec_ms: mean_or_zero(&reexec_ms),
        snapshot_run_snapshots,
        snapshot_digest_match,
    })
}

// --------------------------------------------------------------------------
// Experiment 10 — online migration under load
// --------------------------------------------------------------------------

/// Experiment 10 scenario knobs (CLI `--migrate-rate-mbps` etc., config
/// `[migration]`).
#[derive(Debug, Clone)]
pub struct MigrationSimConfig {
    /// Background-move token-bucket rate in megabits/s
    /// (`--migrate-rate-mbps`).
    pub rate_mbps: f64,
    /// Token-bucket burst in KiB (`--migrate-burst`).
    pub burst_kb: usize,
    /// First retry delay in virtual milliseconds (`--backoff-base-ms`).
    pub backoff_base_ms: f64,
    /// Ceiling on any single retry delay (`--backoff-cap-ms`).
    pub backoff_cap_ms: f64,
    /// Attempts before an event parks as retryable (`--max-attempts`).
    pub max_attempts: usize,
    /// Online AddNode events in the crash-sweep scenario.
    pub add_nodes: usize,
    /// Online DrainNode events.
    pub drain_nodes: usize,
    /// Online AddCluster events.
    pub add_clusters: usize,
    /// Cap on crash positions tested per family (exp9 discipline: odd
    /// stride, last position always included, tested/total reported).
    pub crash_cap: usize,
    /// Foreground degraded-read probes per throttle rate in the
    /// interference curve.
    pub fg_reads: usize,
}

impl Default for MigrationSimConfig {
    fn default() -> Self {
        MigrationSimConfig {
            rate_mbps: 400.0,
            burst_kb: 512,
            backoff_base_ms: 10.0,
            backoff_cap_ms: 1_000.0,
            max_attempts: 5,
            add_nodes: 1,
            drain_nodes: 1,
            add_clusters: 1,
            crash_cap: 48,
            fg_reads: 24,
        }
    }
}

impl MigrationSimConfig {
    /// `(rate_bps, burst_bytes)` for [`Dss::set_migration_throttle`].
    pub fn bucket(&self) -> (f64, f64) {
        (self.rate_mbps * 1e6 / 8.0, (self.burst_kb * 1024) as f64)
    }

    pub fn backoff(&self) -> BackoffPolicy {
        BackoffPolicy {
            base_ms: self.backoff_base_ms,
            cap_ms: self.backoff_cap_ms,
            max_attempts: self.max_attempts,
        }
    }
}

/// Per-family summary of one online-migration-under-load run.
#[derive(Debug, Clone)]
pub struct Exp10Result {
    pub family: CodeFamily,
    // ---- phase A: fault trace through an active migration window
    /// Scheduler counters after the window drained (submitted, completed,
    /// conflicts, source-flips, dest-replans, retries, parked, …).
    pub stats: MigrationStats,
    /// Most online events in flight at once.
    pub concurrent_peak: usize,
    /// Fault-trace events applied mid-window (fail/repair, guarded to
    /// stay within the code's tolerance).
    pub trace_faults_applied: usize,
    /// (stripe, cluster) decode gates passed on the final map.
    pub invariant_checks: usize,
    // ---- phase B: crash sweep over online waves
    pub oracle_digest: u64,
    pub ops: usize,
    pub crash_points_total: usize,
    pub crash_points_tested: usize,
    pub digest_matches: usize,
    /// Crash points that recovered an open online wave and resumed it
    /// move-for-move from the logged plan.
    pub pending_resumes: usize,
    pub decode_checks: usize,
    // ---- phase C: throttle interference curve
    /// `(rate_mbps, foreground degraded-read p50 s, p99 s)` per throttle
    /// rate, ascending.
    pub curve: Vec<(f64, f64, f64)>,
    pub curve_monotone: bool,
}

/// Default throttle sweep for the interference curve: rates straddling
/// the 1 Gb/s cross-cluster gateway around the configured operating point.
pub fn exp10_rates(base_mbps: f64) -> [f64; 4] {
    [base_mbps * 0.25, base_mbps, base_mbps * 4.0, base_mbps * 16.0]
}

fn exp10_scratch_dir(fam: CodeFamily, seed: u64, tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("unilrc-exp10-{}-{fam:?}-{seed}-{tag}", std::process::id()))
}

/// Pump every in-flight online event to completion, reviving parked
/// events as their blockers clear. Bounded so a genuinely stuck event
/// fails loudly instead of spinning.
fn exp10_drain_online(dss: &mut Dss) -> Result<()> {
    for _ in 0..10_000 {
        if dss.online_in_flight() == 0 {
            return Ok(());
        }
        dss.pump_migrations(f64::INFINITY, 64)?;
        if dss.online_in_flight() > 0 && !dss.parked_events().is_empty() {
            dss.retry_parked();
        }
    }
    anyhow::bail!(
        "online migration failed to drain: {} in flight, parked: {:?}",
        dss.online_in_flight(),
        dss.parked_events()
    )
}

/// Submit one event and drain it — the Phase B op wrapper that makes a
/// whole online wave one committed WAL operation (only its `CommitOnline`
/// bumps the op count).
fn exp10_run_online(dss: &mut Dss, ev: TopologyEvent) -> Result<()> {
    dss.submit_topology_event(ev)
        .map_err(|e| anyhow::anyhow!("online submit {ev:?} rejected: {e}"))?;
    exp10_drain_online(dss)
}

/// Phase B op list: the exp9 scenario shape with every topology event
/// executed as an *online* wave instead of a stop-the-world migration.
fn exp10_ops(cfg: &ExpConfig, mcfg: &MigrationSimConfig) -> Vec<DurOp> {
    let mut ops = Vec::new();
    for _ in 0..cfg.stripes {
        ops.push(DurOp::Ingest);
    }
    for _ in 0..mcfg.add_nodes {
        ops.push(DurOp::AddNode);
    }
    ops.push(DurOp::Fail);
    for _ in 0..mcfg.drain_nodes {
        ops.push(DurOp::Drain);
    }
    ops.push(DurOp::Heal);
    for _ in 0..mcfg.add_clusters {
        ops.push(DurOp::AddCluster);
    }
    ops
}

/// Execute one Phase B op. Non-event ops reuse [`exp9_apply_op`]
/// verbatim; topology events go through the online queue. Every
/// parameter stays a pure function of (state, op index), so a recovered
/// run re-executing the tail reproduces the oracle exactly.
fn exp10_apply_op(dss: &mut Dss, op: DurOp, op_index: usize, cfg: &ExpConfig) -> Result<()> {
    match op {
        DurOp::Ingest | DurOp::Fail | DurOp::Heal => exp9_apply_op(dss, op, op_index, cfg),
        DurOp::AddNode => {
            let clusters = dss.topo.clusters();
            let cluster = (0..clusters)
                .map(|i| (op_index + i) % clusters)
                .find(|&c| !dss.topo.is_retired(c))
                .ok_or_else(|| anyhow::anyhow!("no open cluster to grow"))?;
            exp10_run_online(dss, TopologyEvent::AddNode { cluster })
        }
        DurOp::Drain => {
            let node = most_loaded_live_node(dss)
                .ok_or_else(|| anyhow::anyhow!("no live node left to drain"))?;
            exp10_run_online(dss, TopologyEvent::DrainNode { node })
        }
        DurOp::AddCluster => {
            let nodes = dss.topo.max_cluster_size();
            exp10_run_online(dss, TopologyEvent::AddCluster { nodes })
        }
    }
}

/// Measure the throttle-rate × foreground-latency interference curve on
/// one shared gateway/NIC budget.
///
/// Monotone **by construction**, not by luck: migration traffic is
/// admitted at fixed wall-clock ticks (rate-independent instants), and
/// each admission takes everything the token bucket accrued
/// ([`crate::sim::TokenBucket::drain`]). A higher rate therefore injects
/// pointwise-more bytes at identical instants into the same FIFO
/// resources, so every foreground completion time — and hence p50/p99 —
/// is non-decreasing in the rate. Rate-paced `acquire` admissions do
/// *not* have this property (phase alignment can invert single points).
pub fn exp10_interference(
    dss: &mut Dss,
    rates_mbps: &[f64],
    burst: f64,
    fg_reads: usize,
) -> Result<Vec<(f64, f64, f64)>> {
    anyhow::ensure!(fg_reads > 0, "exp10 interference needs at least one foreground probe");
    let stripe = 0;
    let block = 0;
    // fail the probe block's node so every foreground read is degraded
    let victim = dss.metadata().node_of(stripe, block);
    // migration rides a surviving node's NIC and its cluster gateway —
    // the same FIFO resources the degraded read's repair + ship path
    // uses. That shared budget is what the curve measures.
    let src = dss.metadata().node_of(stripe, 1);
    let src_cluster = dss.topo.cluster_of_node(src);
    let dst = (0..dss.topo.total_nodes())
        .find(|&n| dss.topo.is_live(n) && dss.topo.cluster_of_node(n) != src_cluster)
        .ok_or_else(|| anyhow::anyhow!("no cross-cluster migration destination"))?;
    dss.fail_node(victim);

    const TICK: f64 = 0.002; // 2 ms admission cadence
    const FG_GAP: f64 = 0.005; // 5 ms between foreground probes
    let mut curve = Vec::with_capacity(rates_mbps.len());
    for &mbps in rates_mbps {
        dss.quiesce();
        dss.set_migration_throttle(mbps * 1e6 / 8.0, burst);
        let mut lat = Vec::with_capacity(fg_reads);
        let mut tick = 0usize;
        for i in 0..fg_reads {
            let t_issue = i as f64 * FG_GAP;
            while tick as f64 * TICK <= t_issue {
                let now = tick as f64 * TICK;
                let grant = dss.net.migration_grant(now);
                if grant > 0 {
                    dss.net.transfer(now, Endpoint::Node(src), Endpoint::Node(dst), grant);
                }
                tick += 1;
            }
            let done = dss.degraded_read_at(t_issue, stripe, block)?;
            lat.push(done - t_issue);
        }
        lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let p = |q| crate::stats::percentile_sorted(&lat, q).expect("fg_reads > 0 ensured above");
        curve.push((mbps, p(0.50), p(0.99)));
    }
    dss.heal_node(victim);
    Ok(curve)
}

/// Experiment 10 — online migration under load: (A) replay a fault trace
/// through an actively migrating system — concurrent events admitted,
/// conflicting ones serialized with a typed retryable error, a drain
/// source killed mid-move (remaining moves flip onto the batched
/// rebuild), a scale-out destination killed before any byte lands
/// (moves re-plan onto a spare) — and prove every event completes with
/// the one-cluster-loss invariant intact; (B) crash the coordinator at
/// every sampled WAL position inside online waves and prove recovery +
/// plan-tail resume digest-identical to a never-crashed oracle (exp9
/// discipline); (C) measure the throttle interference curve and prove
/// it monotone.
pub fn exp10_migration(cfg: &ExpConfig, mcfg: &MigrationSimConfig) -> Result<Vec<Exp10Result>> {
    let mut out = Vec::new();
    for fam in CodeFamily::paper_baselines() {
        out.push(exp10_family(fam, cfg, mcfg)?);
    }
    Ok(out)
}

fn exp10_family(
    fam: CodeFamily,
    cfg: &ExpConfig,
    mcfg: &MigrationSimConfig,
) -> Result<Exp10Result> {
    let mut det = cfg.clone();
    det.time_compute = false;
    let (rate_bps, burst) = mcfg.bucket();

    // ------------ Phase A: fault trace through an active migration window
    let mut dss = build_dss(fam, &det);
    let mut prng = Prng::new(det.seed);
    dss.ingest_random_stripes(det.stripes, &mut prng)?;
    dss.set_migration_throttle(rate_bps, burst);
    dss.set_migration_backoff(mcfg.backoff());

    // concurrent admissions: a scale-out wave and a drain in flight at once
    dss.submit_topology_event(TopologyEvent::AddNode { cluster: 0 })
        .map_err(|e| anyhow::anyhow!("{fam:?}: online AddNode rejected: {e}"))?;
    dss.pump_migrations(f64::INFINITY, 1)?; // leave the wave part-done
    let victim = (0..dss.topo.total_nodes())
        .filter(|&n| {
            dss.topo.is_active(n)
                && !dss.failed_nodes().contains(&n)
                && dss.topo.cluster_of_node(n) != 0
        })
        .max_by_key(|&n| (dss.metadata().block_map().node_load(n), std::cmp::Reverse(n)))
        .ok_or_else(|| anyhow::anyhow!("{fam:?}: no drain victim outside cluster 0"))?;
    if dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }).is_err() {
        // this family's drain plan collided with the open wave — the
        // events serialize: finish the wave, then the drain admits
        exp10_drain_online(&mut dss)?;
        dss.submit_topology_event(TopologyEvent::DrainNode { node: victim })
            .map_err(|e| anyhow::anyhow!("{fam:?}: serialized drain rejected: {e}"))?;
    }
    let mut concurrent_peak = dss.online_in_flight();

    // claims never open a phantom unavailability window (blocks serve
    // from their source until the move commits)
    anyhow::ensure!(
        dss.availability() == (false, false),
        "{fam:?}: in-flight claims made healthy data look degraded"
    );
    // a second drain of the same node must serialize with a typed,
    // retryable conflict — never a half-claimed map
    match dss.submit_topology_event(TopologyEvent::DrainNode { node: victim }) {
        Err(e @ MigrationError::Conflicting { .. }) => {
            anyhow::ensure!(e.retryable(), "{fam:?}: conflict must be retryable")
        }
        other => anyhow::bail!("{fam:?}: duplicate drain not rejected as conflict: {other:?}"),
    }

    // source death mid-drain: remaining moves flip onto the batched rebuild
    dss.fail_node(victim);
    dss.pump_migrations(f64::INFINITY, 64)?;
    anyhow::ensure!(
        dss.migration_stats().source_flips >= 1,
        "{fam:?}: drain source died mid-move but no move flipped to rebuild"
    );

    // replay the fault trace with a rolling window of online waves open
    let trace = FaultTrace::generate(&dss.topo, &FaultConfig::accelerated(), det.seed ^ 0x10AD);
    let mut trace_faults_applied = 0usize;
    for (i, e) in trace.events.iter().take(16).enumerate() {
        if i % 4 == 0 {
            // keep the window active: another wave joins mid-replay
            // (a conflicting admission just counts toward the stats)
            let clusters = dss.topo.clusters();
            let cluster = (0..clusters)
                .map(|j| (i / 4 + j) % clusters)
                .find(|&c| !dss.topo.is_retired(c))
                .expect("no cluster retires in phase A");
            let _ = dss.submit_topology_event(TopologyEvent::AddNode { cluster });
        }
        match e.kind {
            FaultKind::NodeFail(n)
                if dss.topo.is_live(n)
                    && !dss.failed_nodes().contains(&n)
                    && dss.failed_nodes().len() < 2 =>
            {
                dss.fail_node(n);
                if (0..dss.metadata().stripe_count()).all(|s| dss.stripe_recoverable(s)) {
                    trace_faults_applied += 1;
                } else {
                    dss.heal_node(n); // over-tolerance injection: veto
                }
            }
            FaultKind::NodeRepair(n) if dss.failed_nodes().contains(&n) => {
                if !dss.metadata().blocks_on_node(n).is_empty() {
                    dss.recover_nodes(&[n])?;
                }
                dss.heal_node(n);
                trace_faults_applied += 1;
            }
            _ => {}
        }
        concurrent_peak = concurrent_peak.max(dss.online_in_flight());
        dss.pump_migrations(f64::INFINITY, 2)?;
    }

    // heal outstanding failures, revive parked events, drain the window
    let mut still_failed: Vec<usize> = dss.failed_nodes().iter().copied().collect();
    still_failed.sort_unstable();
    for n in still_failed {
        if !dss.metadata().blocks_on_node(n).is_empty() {
            dss.recover_nodes(&[n])?;
        }
        dss.heal_node(n);
    }
    dss.retry_parked();
    exp10_drain_online(&mut dss)?;

    // destination death: an AddCluster wave with one spare node; its
    // lowest-id target dies before any byte lands, so every move onto it
    // must re-plan onto an invariant-satisfying replacement
    let spare_nodes = dss.topo.max_cluster_size() + 1;
    dss.submit_topology_event(TopologyEvent::AddCluster { nodes: spare_nodes })
        .map_err(|e| anyhow::anyhow!("{fam:?}: online AddCluster rejected: {e}"))?;
    let new_cluster = dss.topo.clusters() - 1;
    let mut targets: Vec<usize> = Vec::new();
    for s in 0..dss.metadata().stripe_count() {
        for b in 0..dss.code.n() {
            if let BlockState::Migrating { to, .. } = dss.metadata().block_state(s, b) {
                if dss.topo.cluster_of_node(to) == new_cluster {
                    targets.push(to);
                }
            }
        }
    }
    targets.sort_unstable();
    targets.dedup();
    let dest = *targets.first().ok_or_else(|| {
        anyhow::anyhow!("{fam:?}: AddCluster wave planned no moves into the new cluster")
    })?;
    let replans0 = dss.migration_stats().dest_replans;
    dss.fail_node(dest);
    exp10_drain_online(&mut dss)?;
    anyhow::ensure!(
        dss.migration_stats().dest_replans > replans0,
        "{fam:?}: destination died before transfer yet no move re-planned"
    );
    dss.heal_node(dest); // the spare slot: nothing landed, nothing to rebuild

    // every admitted event completed; invariants re-proven on the final map
    let stats = dss.migration_stats();
    anyhow::ensure!(
        dss.online_in_flight() == 0 && stats.completed == stats.submitted,
        "{fam:?}: {} of {} admitted events never completed",
        stats.submitted - stats.completed,
        stats.submitted
    );
    anyhow::ensure!(stats.conflicts >= 1, "{fam:?}: conflict probe not counted");
    let mut invariant_checks = 0usize;
    for s in 0..dss.metadata().stripe_count() {
        anyhow::ensure!(dss.stripe_recoverable(s), "{fam:?}: stripe {s} unrecoverable");
        for c in 0..dss.topo.clusters() {
            let blocks = dss.metadata().blocks_in_cluster(s, c);
            if blocks.is_empty() {
                continue;
            }
            anyhow::ensure!(
                dss.code.decode_plan_cached(blocks).is_some(),
                "{fam:?}: stripe {s} would not survive losing cluster {c} after the window"
            );
            invariant_checks += 1;
        }
    }
    dss.quiesce();
    dss.normal_read(0)?;
    drop(dss);

    // ---------- Phase B: exp9-discipline crash sweep over online waves
    let ops = exp10_ops(&det, mcfg);
    let oracle_dir = exp10_scratch_dir(fam, det.seed, "oracle");
    let _ = std::fs::remove_dir_all(&oracle_dir);
    let mut odss = build_dss(fam, &det);
    odss.enable_durability(
        &oracle_dir,
        DurabilityOptions { sync_every: 8, snapshot_every: usize::MAX },
    )?;
    for (i, &op) in ops.iter().enumerate() {
        exp10_apply_op(&mut odss, op, i, &det)?;
    }
    let oracle_digest = odss.capture_state().digest();
    let blocks = odss.export_blocks();
    let journal = odss.journal().expect("durability enabled above");
    anyhow::ensure!(
        journal.committed_ops() == ops.len() as u64,
        "{fam:?}: every driver op must commit exactly one WAL unit ({} != {})",
        journal.committed_ops(),
        ops.len()
    );
    let wal_records = journal.wal_records();
    drop(odss);

    let segments = list_segments(&oracle_dir)?;
    anyhow::ensure!(segments.len() == 1, "oracle journal must hold exactly one segment");
    let wal_path = segments[0].1.clone();
    let wal_img = std::fs::read(&wal_path)?;
    let (records, end) = scan_segment(&wal_img);
    anyhow::ensure!(end == ScanEnd::Clean, "oracle WAL must scan clean, got {end:?}");
    anyhow::ensure!(records.len() as u64 == wal_records, "oracle WAL record count mismatch");
    let mut positions: Vec<usize> = Vec::with_capacity(records.len() * 2 + 1);
    for (i, r) in records.iter().enumerate() {
        let next = records.get(i + 1).map_or(wal_img.len(), |n| n.offset);
        positions.push(r.offset);
        positions.push(r.offset + (next - r.offset) / 2);
    }
    positions.push(wal_img.len());
    let total = positions.len();
    let tested_idx: Vec<usize> = if mcfg.crash_cap > 0 && total > mcfg.crash_cap {
        let mut step = total.div_ceil(mcfg.crash_cap);
        if step % 2 == 0 {
            step += 1; // odd stride: sample boundaries *and* torn tails
        }
        let mut idx: Vec<usize> = (0..total).step_by(step).collect();
        if idx.last() != Some(&(total - 1)) {
            idx.push(total - 1);
        }
        idx
    } else {
        (0..total).collect()
    };

    let store = ManifestStore::new(&oracle_dir);
    let crash_dir = exp10_scratch_dir(fam, det.seed, "crash");
    let (mut digest_matches, mut pending_resumes) = (0usize, 0usize);
    let mut decode_checks = 0usize;
    for &idx in &tested_idx {
        let cut = positions[idx];
        let _ = std::fs::remove_dir_all(&crash_dir);
        std::fs::create_dir_all(&crash_dir)?;
        std::fs::copy(store.current_path(), crash_dir.join(MANIFEST_CURRENT))?;
        if store.prev_path().exists() {
            std::fs::copy(store.prev_path(), crash_dir.join(MANIFEST_PREV))?;
        }
        std::fs::write(
            crash_dir.join(wal_path.file_name().expect("segment file name")),
            &wal_img[..cut],
        )?;

        let rec = recover(&crash_dir).map_err(|e| {
            anyhow::anyhow!("{fam:?}: recovery at crash position {cut} failed: {e}")
        })?;
        anyhow::ensure!(
            rec.pending_online.len() <= 1,
            "{fam:?}: scenario runs one online wave at a time, recovered {}",
            rec.pending_online.len()
        );

        let code = det.scheme.build(fam);
        let (strategy, _) = strategy_and_topo(fam, &code);
        let mut rdss = Dss::restore(
            code,
            strategy,
            &rec.state,
            blocks.clone(),
            NetConfig::default().with_cross_gbps(det.cross_gbps),
            det.engine.clone(),
            DssConfig {
                block_size: det.block_size,
                aggregated: det.aggregated,
                time_compute: false,
            },
        )?;

        let mut next = rec.committed_ops as usize;
        anyhow::ensure!(
            next <= ops.len(),
            "{fam:?}: recovered {next} committed ops, scenario has only {}",
            ops.len()
        );
        if !rec.pending_online.is_empty() {
            // crash mid-wave: the op at `next` is the interrupted event —
            // resume its logged plan tail instead of re-submitting
            let is_event = ops.get(next).is_some_and(|op| {
                matches!(op, DurOp::AddNode | DurOp::Drain | DurOp::AddCluster)
            });
            anyhow::ensure!(is_event, "{fam:?}: pending online wave at a non-event op");
            rdss.resume_online(&rec.pending_online);
            exp10_drain_online(&mut rdss)?;
            pending_resumes += 1;
            next += 1;
        }
        for (i, &op) in ops.iter().enumerate().skip(next) {
            exp10_apply_op(&mut rdss, op, i, &det)?;
        }
        let got = rdss.capture_state().digest();
        anyhow::ensure!(
            got == oracle_digest,
            "{fam:?}: crash at WAL byte {cut} diverged: {got:#x} != oracle {oracle_digest:#x}"
        );
        digest_matches += 1;
        for s in 0..rdss.metadata().stripe_count() {
            for c in 0..rdss.topo.clusters() {
                let in_cluster = rdss.metadata().blocks_in_cluster(s, c);
                if in_cluster.is_empty() {
                    continue;
                }
                anyhow::ensure!(
                    rdss.code.decode_plan_cached(in_cluster).is_some(),
                    "{fam:?}: stripe {s} undecodable after losing cluster {c} (crash at {cut})"
                );
                decode_checks += 1;
            }
        }
    }
    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&oracle_dir);

    // ------------------------ Phase C: throttle interference curve
    let mut cdss = build_dss(fam, &det);
    let mut prng = Prng::new(det.seed ^ 0xC10);
    cdss.ingest_random_stripes(det.stripes, &mut prng)?;
    let rates = exp10_rates(mcfg.rate_mbps);
    let curve = exp10_interference(&mut cdss, &rates, burst, mcfg.fg_reads)?;
    let curve_monotone =
        curve.windows(2).all(|w| w[1].1 + 1e-9 >= w[0].1 && w[1].2 + 1e-9 >= w[0].2);
    anyhow::ensure!(
        curve_monotone,
        "{fam:?}: interference curve is not monotone in the throttle rate: {curve:?}"
    );

    Ok(Exp10Result {
        family: fam,
        stats,
        concurrent_peak,
        trace_faults_applied,
        invariant_checks,
        oracle_digest,
        ops: ops.len(),
        crash_points_total: total,
        crash_points_tested: tested_idx.len(),
        digest_matches,
        pending_resumes,
        decode_checks,
        curve,
        curve_monotone,
    })
}

// ----------------------------------------------------------------- exp11

/// Experiment 11 (latent-error scrubbing) configuration: a
/// scrub-interval × sector-error-rate grid replayed per family.
#[derive(Debug, Clone)]
pub struct ScrubSimConfig {
    /// Scrub-period sweep points (hours between pass starts).
    pub intervals_hours: Vec<f64>,
    /// Sector-error-rate sweep points, as mean hours between latent
    /// errors per node (smaller = dirtier disks).
    pub sector_mtte_hours: Vec<f64>,
    /// Node/cluster clocks and horizon shared by every grid cell (its
    /// `sector_mtte_hours` is overridden per cell).
    pub fault: FaultConfig,
    /// Bytes verified per node per pass.
    pub node_bytes: u64,
    /// Background budget the scrubber shares with migration traffic:
    /// token-bucket refill (bytes per virtual hour) and burst capacity.
    pub rate_bytes_per_hour: f64,
    pub burst_bytes: f64,
    /// Replay admission cadence (hours).
    pub tick_hours: f64,
}

impl Default for ScrubSimConfig {
    fn default() -> Self {
        ScrubSimConfig {
            intervals_hours: vec![12.0, 48.0],
            sector_mtte_hours: vec![50.0, 200.0],
            fault: FaultConfig::accelerated(),
            node_bytes: 1 << 20,
            // generous enough that a pass over the widest paper topology
            // (~200 nodes at S210) finishes well inside the shortest
            // sweep interval — the starved regime is exercised by tests
            rate_bytes_per_hour: 256.0 * (1 << 20) as f64,
            burst_bytes: 8.0 * (1 << 20) as f64,
            tick_hours: 0.25,
        }
    }
}

/// One grid cell of the exp11 sweep: simulated scrub outcome next to the
/// closed-form latent-error chain it is differentially tested against.
#[derive(Debug, Clone)]
pub struct Exp11Row {
    pub family: CodeFamily,
    pub interval_hours: f64,
    pub sector_mtte_hours: f64,
    pub injected: usize,
    pub detected: usize,
    /// Mean injection→detection delay: simulated vs `T/2` closed form.
    pub sim_dwell_hours: f64,
    pub markov_dwell_hours: f64,
    /// Steady-state undetected errors per node: simulated (Little's-law
    /// meter) vs `λ̂·T/2` with `λ̂` estimated from the trace, exp7-style.
    pub sim_undetected_per_node: f64,
    pub markov_undetected_per_node: f64,
    /// Family-coupled closed form: fraction of time failures + silent
    /// corruption exceed the family's tolerance
    /// ([`markov::latent_loss_fraction`]).
    pub loss_fraction_markov: f64,
    /// ∫ undetected errors on nodes whose cluster already has a down
    /// member — the scheduler's stripes-at-risk signal, integrated.
    pub at_risk_block_hours: f64,
    pub scrubbed_bytes: u64,
    pub granted_bytes: u64,
}

/// The sweep result plus its determinism witness.
#[derive(Debug, Clone)]
pub struct Exp11Result {
    pub rows: Vec<Exp11Row>,
    /// Mixes every trace digest and every [`ScrubReport`] digest —
    /// same seed ⇒ identical, like exp7/exp8.
    ///
    /// [`ScrubReport`]: crate::sim::faults::ScrubReport
    pub digest: u64,
}

/// Experiment 11 — periodic scrubbing vs latent sector errors: replay a
/// seeded latent-error + node/cluster fault schedule through the
/// budget-throttled scrubber ([`replay_scrub`]) on every family's
/// placement, for every (scrub interval × sector rate) grid cell, and put
/// the measurements next to the closed-form latent-error chain
/// ([`markov::latent_undetected_mean`], [`markov::latent_loss_fraction`]).
/// Deterministic: the result digest is a pure function of
/// `(scheme, config, seed)`.
pub fn exp11_scrub(cfg: &ExpConfig, scfg: &ScrubSimConfig) -> Result<Exp11Result> {
    anyhow::ensure!(!scfg.intervals_hours.is_empty(), "exp11 needs ≥ 1 scrub interval");
    anyhow::ensure!(!scfg.sector_mtte_hours.is_empty(), "exp11 needs ≥ 1 sector-error rate");
    anyhow::ensure!(
        scfg.sector_mtte_hours.iter().all(|&m| m > 0.0),
        "sector MTTE must be positive (it is the sweep axis, 0 disables injection)"
    );
    let mut rows = Vec::new();
    let mut digest = DIGEST_SEED;
    for (fi, fam) in CodeFamily::paper_baselines().into_iter().enumerate() {
        let code = cfg.scheme.build(fam);
        let (_, topo) = strategy_and_topo(fam, &code);
        let topo = match &cfg.topology {
            Some(sizes) => custom_topology(fam, &code, sizes)?,
            None => topo,
        };
        let live = (0..topo.total_nodes()).filter(|&n| topo.is_live(n)).count();
        let f_tol = family_tolerance(cfg.scheme, fam);
        // average blocks a node hosts — converts the node-level error
        // rate into the per-block corruption field of the closed form
        let blocks_per_node = (cfg.stripes.max(1) * code.n()) as f64 / live as f64;
        for (ii, &interval) in scfg.intervals_hours.iter().enumerate() {
            for (ri, &mtte) in scfg.sector_mtte_hours.iter().enumerate() {
                let fault = FaultConfig { sector_mtte_hours: mtte, ..scfg.fault };
                let seed = cfg.seed
                    ^ (0x1100_0000_u64 + ((fi as u64) << 16) + ((ii as u64) << 8) + ri as u64);
                let trace = FaultTrace::generate(&topo, &fault, seed);
                let sc = ScrubConfig {
                    interval_hours: interval,
                    node_bytes: scfg.node_bytes,
                    rate_bytes_per_hour: scfg.rate_bytes_per_hour,
                    burst_bytes: scfg.burst_bytes,
                    tick_hours: scfg.tick_hours,
                };
                let rep = replay_scrub(&topo, &trace, &sc);
                let horizon = fault.horizon_hours;
                // trace-estimated arrival rate (per node-hour), exp7-style
                let lambda_hat = rep.injected as f64 / (live as f64 * horizon);
                let sim_undet = rep.undetected_block_hours / horizon / live as f64;
                let node_lambda =
                    if fault.node_mttf_hours > 0.0 { 1.0 / fault.node_mttf_hours } else { 0.0 };
                let node_mu =
                    if fault.node_mttr_hours > 0.0 { 1.0 / fault.node_mttr_hours } else { 0.0 };
                let p_block = 1.0
                    - (-(lambda_hat / blocks_per_node) * interval / 2.0).exp();
                let loss = markov::latent_loss_fraction(
                    code.n(),
                    f_tol,
                    node_lambda,
                    node_mu,
                    p_block,
                );
                digest = digest_mix(digest, trace.digest());
                digest = digest_mix(digest, rep.digest());
                rows.push(Exp11Row {
                    family: fam,
                    interval_hours: interval,
                    sector_mtte_hours: mtte,
                    injected: rep.injected,
                    detected: rep.detected,
                    sim_dwell_hours: rep.mean_dwell_hours,
                    markov_dwell_hours: markov::scrub_mean_dwell_hours(interval),
                    sim_undetected_per_node: sim_undet,
                    markov_undetected_per_node: markov::latent_undetected_mean(
                        lambda_hat, interval,
                    ),
                    loss_fraction_markov: loss,
                    at_risk_block_hours: rep.at_risk_block_hours,
                    scrubbed_bytes: rep.scrubbed_bytes,
                    granted_bytes: rep.granted_bytes,
                });
            }
        }
    }
    Ok(Exp11Result { rows, digest })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic test config: `time_compute: false` keeps asserted
    /// latencies pure functions of the virtual network — host load and
    /// worker-thread scheduling can no longer flake the ordering asserts.
    fn tiny() -> ExpConfig {
        ExpConfig { block_size: 16 * 1024, stripes: 2, time_compute: false, ..Default::default() }
    }

    #[test]
    fn exp1_shape() {
        let rows = exp1_normal_read(&tiny()).unwrap();
        assert_eq!(rows.len(), 5);
        let uni = rows.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc = rows.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!(uni >= olrc * 0.99, "UniLRC {uni} vs OLRC {olrc}");
    }

    #[test]
    fn exp2_burst_runs() {
        let rows = exp2_degraded_burst(&tiny()).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.value > 0.0, "{:?}", r.family);
        }
    }

    #[test]
    fn exp2_and_exp3_shapes() {
        let lat = exp2_degraded_read(&tiny()).unwrap();
        let uni = lat.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc = lat.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!(uni < olrc, "degraded latency: UniLRC {uni} < OLRC {olrc}");

        let rec = exp3_reconstruction(&tiny()).unwrap();
        let uni = rec.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        for r in &rec {
            assert!(uni >= r.value * 0.95, "{:?}", r.family);
        }
    }

    #[test]
    fn exp4_unilrc_flat_baselines_climb() {
        // larger blocks so bandwidth (not the fixed RTT) dominates
        let cfg = ExpConfig {
            block_size: 256 * 1024,
            stripes: 2,
            time_compute: false,
            ..Default::default()
        };
        let sweep = exp4_bandwidth(&cfg, &[0.5, 10.0]).unwrap();
        let uni_lo = sweep[0].1.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let uni_hi = sweep[1].1.iter().find(|r| r.family == CodeFamily::UniLrc).unwrap().value;
        let olrc_lo = sweep[0].1.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        let olrc_hi = sweep[1].1.iter().find(|r| r.family == CodeFamily::Olrc).unwrap().value;
        assert!((uni_hi - uni_lo).abs() / uni_lo < 0.25, "UniLRC flat-ish");
        assert!(olrc_hi > olrc_lo * 1.5, "OLRC climbs with bandwidth: {olrc_lo} -> {olrc_hi}");
    }

    #[test]
    fn exp7_smoke_all_families() {
        let cfg = ExpConfig { block_size: 4 * 1024, stripes: 2, ..tiny() };
        let fcfg = FaultSimConfig {
            fault: FaultConfig {
                node_mttf_hours: 300.0,
                node_mttr_hours: 10.0,
                cluster_mttf_hours: 1_500.0,
                cluster_mttr_hours: 5.0,
                sector_mtte_hours: 0.0,
                horizon_hours: 600.0,
            },
            tenants: 2,
            objects_per_tenant: 6,
            reads_per_event: 1,
            measure_cap: 8,
        };
        let rows = exp7_faults(&cfg, &fcfg).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.events > 0, "{:?}", r.family);
            assert!(r.node_failures > 0, "{:?}", r.family);
            assert!(r.degraded_hours > 0.0, "{:?}", r.family);
            assert!(r.degraded_hours <= fcfg.fault.horizon_hours + 1e-9);
            assert!(r.unavailable_hours <= r.degraded_hours + 1e-9);
            assert!(r.markov_degraded_frac > 0.0 && r.markov_degraded_frac < 1.0);
        }
    }

    #[test]
    fn family_tolerance_matches_table() {
        assert_eq!(family_tolerance(Scheme::S42, CodeFamily::UniLrc), 7);
        assert_eq!(family_tolerance(Scheme::S42, CodeFamily::Alrc), 7);
        assert_eq!(family_tolerance(Scheme::S42, CodeFamily::Olrc), 11);
    }

    #[test]
    fn predicted_patterns_cover_single_node_failures() {
        // S136 keeps this test's cache keys disjoint from every other
        // test in this binary (keys embed the code name), so the
        // `inserted > 0` assert cannot race concurrent demand inserts.
        let cfg = ExpConfig { block_size: 1024, stripes: 2, scheme: Scheme::S136, ..tiny() };
        let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
        let mut p = Prng::new(5);
        dss.ingest_random_stripes(2, &mut p).unwrap();
        let trace = FaultTrace::generate(&dss.topo, &FaultConfig::accelerated(), 5);
        let patterns = predicted_patterns(&dss, &trace);
        assert!(!patterns.is_empty());
        for pat in &patterns {
            assert!(!pat.is_empty());
            assert!(pat.windows(2).all(|w| w[0] < w[1]), "sorted dedup {pat:?}");
        }
        // warm-up inserts them and repairs still verify (recover_node
        // checks rebuilt bytes against ground truth internally)
        let inserted = dss.prefetch_plans(&patterns);
        assert!(inserted > 0);
        let node = dss.metadata().node_of(0, 0);
        dss.fail_node(node);
        dss.recover_node(node).unwrap();
        dss.heal_node(node);
    }

    #[test]
    fn exp8_smoke_all_families() {
        let cfg = ExpConfig { block_size: 8 * 1024, stripes: 2, ..tiny() };
        let ecfg = ElasticConfig {
            add_nodes: 1,
            drain_nodes: 1,
            add_clusters: 1,
            cluster_nodes: 0,
            fault_horizon_hours: 150.0,
        };
        let rows = exp8_elastic(&cfg, &ecfg).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert_eq!(r.events, 4, "{:?}: add + drain + add-cluster + post-scale drain", r.family);
            assert!(r.moves > 0, "{:?}: events must move blocks", r.family);
            assert!(r.invariant_checks > 0, "{:?}", r.family);
            assert!(r.migration_seconds > 0.0, "{:?}", r.family);
            assert!(r.migrated_bytes >= r.moves * cfg.block_size);
            assert!(r.post_scale_fault_events > 0, "{:?}", r.family);
            assert!((0.0..1.0).contains(&r.exposure_prob), "{:?}", r.family);
            assert!(r.final_clusters >= 7, "{:?}: one cluster added", r.family);
            // the per-event timing rows (exp9's baseline) cover every event
            assert_eq!(r.event_timings.len(), r.events, "{:?}", r.family);
            let virtual_sum: f64 = r.event_timings.iter().map(|&(_, _, s, _)| s).sum();
            assert!((virtual_sum - r.migration_seconds).abs() < 1e-9, "{:?}", r.family);
            for &(_, wall_ms, _, moves) in &r.event_timings {
                assert!(wall_ms.is_finite() && wall_ms >= 0.0, "{:?}", r.family);
                assert!(moves <= r.moves, "{:?}", r.family);
            }
        }
    }

    #[test]
    fn exp9_smoke_all_families() {
        let cfg = ExpConfig { block_size: 4 * 1024, stripes: 2, ..tiny() };
        let dcfg = DurabilitySimConfig {
            wal_sync_every: 4,
            snapshot_every: 3,
            add_nodes: 1,
            drain_nodes: 1,
            add_clusters: 1,
            fault_ops: 0,
            crash_cap: 7,
        };
        let rows = exp9_durability(&cfg, &dcfg).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            // 2 ingests + add-node + fail + drain + heal + add-cluster
            assert_eq!(r.ops, 7, "{:?}", r.family);
            assert!(r.wal_records >= r.ops as u64, "{:?}", r.family);
            assert!(r.wal_bytes > 0, "{:?}", r.family);
            assert!(r.crash_points_total >= r.crash_points_tested, "{:?}", r.family);
            assert!(r.crash_points_tested > 0, "{:?}", r.family);
            // the acceptance gate: every tested crash point recovered to
            // the byte-identical oracle map
            assert_eq!(r.digest_matches, r.crash_points_tested, "{:?}", r.family);
            // the odd sampling stride guarantees mid-record crash points
            assert!(r.torn_tails > 0, "{:?}: no torn-tail crash tested", r.family);
            assert!(r.decode_checks > 0, "{:?}", r.family);
            assert_eq!(r.reconstructed_blocks, r.crash_points_tested, "{:?}", r.family);
            assert!(r.snapshot_run_snapshots > 1, "{:?}: cadence never fired", r.family);
            assert!(r.snapshot_digest_match, "{:?}", r.family);
        }
    }

    #[test]
    fn exp10_smoke_all_families() {
        let cfg = ExpConfig { block_size: 4 * 1024, stripes: 2, ..tiny() };
        let mcfg = MigrationSimConfig { crash_cap: 12, fg_reads: 8, ..Default::default() };
        let rows = exp10_migration(&cfg, &mcfg).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            let fam = r.family;
            // every admitted event completed, including the ones that
            // lost their source or destination mid-move
            assert_eq!(r.stats.completed, r.stats.submitted, "{fam:?}");
            assert!(r.stats.conflicts >= 1, "{fam:?}: conflict probe uncounted");
            assert!(r.stats.source_flips >= 1, "{fam:?}: no source-death flip");
            assert!(r.stats.dest_replans >= 1, "{fam:?}: no dest-death re-plan");
            assert!(r.concurrent_peak >= 2 || r.stats.conflicts >= 1, "{fam:?}");
            assert!(r.invariant_checks > 0, "{fam:?}");
            // 2 ingests + add-node + fail + drain + heal + add-cluster
            assert_eq!(r.ops, 7, "{fam:?}");
            assert!(r.crash_points_tested > 0, "{fam:?}");
            assert_eq!(r.digest_matches, r.crash_points_tested, "{fam:?}");
            // at least one crash point recovered an open wave and
            // resumed it from the logged plan
            assert!(r.pending_resumes > 0, "{fam:?}: no mid-wave crash resumed");
            assert!(r.decode_checks > 0, "{fam:?}");
            assert_eq!(r.curve.len(), 4, "{fam:?}");
            assert!(r.curve_monotone, "{fam:?}: {:?}", r.curve);
        }
    }

    #[test]
    fn custom_topology_validates_per_family() {
        let code = Scheme::S42.build(CodeFamily::UniLrc);
        // 6 groups of 7 → needs ≥ 6 clusters of ≥ 7 nodes
        assert!(custom_topology(CodeFamily::UniLrc, &code, &[9, 9, 9, 8, 8, 7]).is_ok());
        assert!(custom_topology(CodeFamily::UniLrc, &code, &[9, 9, 9, 8, 8]).is_err());
        assert!(custom_topology(CodeFamily::UniLrc, &code, &[9, 9, 9, 8, 8, 3]).is_err());
        // asymmetric topology drives a full experiment end to end (sizes
        // satisfy every family: OLRC's chunks need ≥ 11 nodes per cluster)
        let cfg = ExpConfig {
            block_size: 4 * 1024,
            stripes: 2,
            topology: Some(vec![14, 13, 13, 12, 12, 11, 11]),
            ..tiny()
        };
        let rows = exp1_normal_read(&cfg).unwrap();
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().all(|r| r.value > 0.0));
    }

    #[test]
    fn predictor_learns_only_new_observations() {
        let cfg = ExpConfig { block_size: 1024, stripes: 2, ..tiny() };
        let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
        let mut p = Prng::new(3);
        dss.ingest_random_stripes(2, &mut p).unwrap();
        let mut pred = PatternPredictor::new();
        let node = dss.metadata().node_of(0, 0);
        // a UniLRC node hosts ≤ 1 block per stripe and every block is
        // grouped, so node-only history normalizes to nothing (in-group
        // singles repair by XOR and never consult the plan cache)…
        assert!(pred.observe(&dss, &[node], &[]).is_empty());
        assert_eq!(pred.observed(), (1, 0), "…but the sighting is still recorded");
        // a cluster observation predicts whole-cluster patterns, once
        let cluster = dss.metadata().cluster_of(0, 0);
        let first = pred.observe(&dss, &[], &[cluster]);
        assert!(!first.is_empty(), "first cluster sighting predicts recurrence");
        for pat in &first {
            assert!(pat.len() > 1, "cluster patterns are multi-block: {pat:?}");
            assert!(pat.windows(2).all(|w| w[0] < w[1]), "sorted {pat:?}");
        }
        assert!(pred.observe(&dss, &[], &[cluster]).is_empty());
        assert_eq!(pred.observed(), (1, 1));
    }

    #[test]
    fn exp6_runs() {
        let mut cfg = tiny();
        cfg.stripes = 3;
        let res = exp6_production(&cfg, 10, 8).unwrap();
        assert_eq!(res.len(), 5);
        for r in &res {
            assert!(r.normal_mean_ms > 0.0);
            assert!(r.degraded_mean_ms > 0.0);
        }
    }
}
