//! Shared sample statistics — the one percentile implementation in the
//! crate.
//!
//! History: the client driver, the serving-plane load generator and the
//! experiment harness each grew a private percentile helper with a
//! different convention (`p` in 0..=100 vs `q` in 0..=1) and a different
//! empty-input behavior (panic vs a silent `0.0` — the latter let a dead
//! server pass a p99 gate vacuously). This module fixes one convention —
//! nearest-rank, `q` in `0.0..=1.0` — and makes the empty case typed:
//! callers must decide what an absent percentile means for them.

/// Nearest-rank percentile of a sample, `q` in `0.0..=1.0` (`q = 0.0` is
/// the minimum, `q = 1.0` the maximum). Sorts a copy of the input.
///
/// Returns `None` on an empty sample. Panics on NaN samples or an
/// out-of-range `q` — both are caller bugs, never data.
pub fn percentile(samples: &[f64], q: f64) -> Option<f64> {
    let mut s = samples.to_vec();
    assert!(s.iter().all(|v| !v.is_nan()), "percentile over NaN samples");
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN excluded above"));
    percentile_sorted(&s, q)
}

/// [`percentile`] over an already ascending-sorted, NaN-free slice (the
/// hot-path variant: no copy, no re-sort).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> Option<f64> {
    assert!((0.0..=1.0).contains(&q), "percentile rank {q} outside 0.0..=1.0");
    if sorted.is_empty() {
        return None;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    Some(sorted[idx.min(sorted.len() - 1)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none_not_zero() {
        assert_eq!(percentile(&[], 0.99), None);
        assert_eq!(percentile_sorted(&[], 0.5), None);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&[7.5], q), Some(7.5));
        }
    }

    #[test]
    fn boundaries_are_min_and_max() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&s, 0.0), Some(1.0));
        assert_eq!(percentile(&s, 1.0), Some(100.0));
        // nearest-rank interior points on 100 samples
        assert_eq!(percentile(&s, 0.50), Some(51.0));
        assert_eq!(percentile(&s, 0.99), Some(99.0));
    }

    #[test]
    fn unsorted_input_is_sorted_internally() {
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 0.0), Some(1.0));
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 1.0), Some(9.0));
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 0.5), Some(5.0));
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_samples_are_rejected() {
        let _ = percentile(&[1.0, f64::NAN], 0.5);
    }

    #[test]
    #[should_panic(expected = "outside 0.0..=1.0")]
    fn percent_style_rank_is_rejected() {
        // the old client-side convention (p in 0..=100) must fail loudly,
        // not silently read the max
        let _ = percentile(&[1.0, 2.0], 99.0);
    }
}
