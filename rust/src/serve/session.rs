//! Pipelined data-plane sessions.
//!
//! One session = one TCP connection = two tasks:
//!
//! * the **reader/executor** decodes frames as they arrive, checks the
//!   request's epoch, takes an admission permit, runs the op against
//!   the shared coordinator, and queues the encoded response — strictly
//!   in request order, which is the protocol's ordering guarantee;
//! * the **writer** drains the response queue with the undermoon
//!   `CircularBufWriter` discipline: on each wakeup it takes everything
//!   queued (blocking on the first frame, then `try_recv` until empty)
//!   and issues **one** `write_all` + `flush` for the whole batch, so a
//!   pipelined burst of N requests costs O(1) syscalls, not O(N).
//!
//! Backpressure is structural: the bounded response channel plus the
//! per-tenant admission window stop the reader from pulling more work
//! off the socket than the server is willing to hold in flight.

use crate::serve::protocol::{take_frame, OpKind, Request, Response};
use crate::serve::server::ServeState;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use tokio::net::TcpStream;
use tokio::sync::mpsc;

/// Run one session to completion (client disconnect or protocol error).
pub async fn run_session(stream: TcpStream, state: Arc<ServeState>) {
    let (mut reader, mut writer) = stream.into_split();
    let (tx, mut rx) = mpsc::channel::<Vec<u8>>(256);

    let wstate = Arc::clone(&state);
    let writer_task = tokio::spawn(async move {
        let mut buf: Vec<u8> = Vec::with_capacity(4096);
        while let Some(first) = rx.recv().await {
            buf.clear();
            buf.extend_from_slice(&first);
            let mut frames = 1u64;
            while let Ok(more) = rx.try_recv() {
                buf.extend_from_slice(&more);
                frames += 1;
            }
            if writer.write_all(&buf).await.is_err() || writer.flush().await.is_err() {
                break;
            }
            wstate.stats.frames_out.fetch_add(frames, Ordering::Relaxed);
            wstate.stats.flushes.fetch_add(1, Ordering::Relaxed);
        }
    });

    let mut acc: Vec<u8> = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    'session: loop {
        let n = match reader.read(&mut chunk).await {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        acc.extend_from_slice(&chunk[..n]);
        loop {
            match take_frame(&acc) {
                Ok(Some((payload, used))) => {
                    state.stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp = match Request::decode(payload) {
                        Ok(req) => handle(&state, &req),
                        Err(detail) => {
                            state.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                            Response::Error { id: 0, detail }
                        }
                    };
                    acc.drain(..used);
                    if tx.send(resp.encode()).await.is_err() {
                        break 'session;
                    }
                }
                Ok(None) => break,
                Err(_) => {
                    // Unframeable input: the stream cannot be resynced.
                    state.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    break 'session;
                }
            }
        }
    }
    drop(tx);
    let _ = writer_task.await;
}

/// Execute one request: epoch gate → admission → coordinator op.
pub(crate) fn handle(state: &ServeState, req: &Request) -> Response {
    // Cheap staleness gate on the lock-free epoch mirror: a stale
    // client is redirected without costing an admission slot or the
    // coordinator lock.
    let current = state.epoch.load(Ordering::Acquire);
    if req.epoch != current {
        state.stats.stale_redirects.fetch_add(1, Ordering::Relaxed);
        return Response::StaleEpoch { id: req.id, current };
    }
    let _permit = state.admission.acquire(req.tenant, req.op.is_background(), state.block_size);
    let mut dss = state.dss();
    // Re-check under the lock — an epoch bump may have raced admission,
    // and the contract is that no op executes against routing the
    // client does not hold.
    let current = dss.epoch();
    if req.epoch != current {
        state.stats.stale_redirects.fetch_add(1, Ordering::Relaxed);
        return Response::StaleEpoch { id: req.id, current };
    }
    let stripe = req.stripe as usize;
    if stripe >= dss.metadata().stripe_count() {
        state.stats.op_errors.fetch_add(1, Ordering::Relaxed);
        return Response::Error { id: req.id, detail: format!("no such stripe {stripe}") };
    }
    let result = match req.op {
        OpKind::Get => {
            let count = (req.block as usize).clamp(1, dss.code.k());
            let targets: Vec<(usize, usize)> = (0..count).map(|b| (stripe, b)).collect();
            dss.parallel_read(&targets)
        }
        OpKind::DegradedRead => dss.degraded_read(stripe, req.block as usize),
        OpKind::Repair => dss.reconstruct(stripe, req.block as usize),
    };
    match result {
        Ok(op) => {
            state.stats.responses_ok.fetch_add(1, Ordering::Relaxed);
            Response::Ok {
                id: req.id,
                epoch: current,
                latency_us: (op.latency * 1e6) as u64,
                bytes: op.bytes as u64,
            }
        }
        Err(e) => {
            state.stats.op_errors.fetch_add(1, Ordering::Relaxed);
            Response::Error { id: req.id, detail: e.to_string() }
        }
    }
}
