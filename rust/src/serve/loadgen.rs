//! Closed-loop load generator for the serving plane.
//!
//! One std thread per session, each pinned to a tenant and drawing
//! object sizes from that tenant's [`WorkloadSpec`] mix. A session
//! works in pipelined batches: it encodes a whole batch of requests,
//! ships them in **one** batched socket write, then reads the batch's
//! responses — verifying they come back strictly in request order.
//! Repair ops ride at the *end* of a batch so their QoS wait (yield to
//! foreground, token bucket) never sits in front of a measured read.
//!
//! Epoch handling is the client half of the serving plane's metadata
//! protocol: the session boots by fetching an epoch-stamped routing
//! table from the control API and stamps every request with it. When a
//! topology event bumps the epoch mid-run, in-flight requests come back
//! `StaleEpoch`; the session refreshes its table over HTTP and retries
//! just the redirected requests (bounded attempts), counting any that
//! never recover. A clean run reports zero `protocol_errors`, zero
//! `unrecovered_redirects`, and zero `in_order_violations` — those are
//! the CI-gated invariants; latency percentiles are the CI-gated
//! performance surface.

use crate::bench_util::JsonReport;
use crate::client::WorkloadSpec;
use crate::prng::Prng;
use crate::serve::http::{json_pairs, json_u64};
use crate::serve::protocol::{take_frame, OpKind, Request, Response};
use std::io::{Read as IoRead, Write as IoWrite};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Data-plane address (`host:port`).
    pub data_addr: String,
    /// Control-plane address (`host:port`).
    pub http_addr: String,
    pub sessions: usize,
    pub duration: Duration,
    /// Requests kept in flight per batch (pipeline depth).
    pub pipeline: usize,
    pub seed: u64,
    /// Submit `add_node` this long into the run (exercises the
    /// stale-epoch redirect path live); `None` = steady state.
    pub topology_event_at: Option<Duration>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            data_addr: "127.0.0.1:4700".to_string(),
            http_addr: "127.0.0.1:4701".to_string(),
            sessions: 3,
            duration: Duration::from_secs(10),
            pipeline: 16,
            seed: 42,
            topology_event_at: None,
        }
    }
}

/// Aggregated closed-loop outcome across all sessions.
#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    pub requests: u64,
    pub ok: u64,
    pub repairs: u64,
    pub stale_redirects: u64,
    pub unrecovered_redirects: u64,
    pub protocol_errors: u64,
    pub op_errors: u64,
    pub in_order_violations: u64,
    /// Foreground (get / degraded-read) wall-latency percentiles, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

#[derive(Default)]
struct SessionOutcome {
    latencies_ms: Vec<f64>,
    requests: u64,
    ok: u64,
    repairs: u64,
    stale: u64,
    unrecovered: u64,
    protocol_errors: u64,
    op_errors: u64,
    in_order_violations: u64,
}

/// One logical operation, re-sendable across stale-epoch retries.
#[derive(Clone, Copy)]
struct OpSpec {
    op: OpKind,
    stripe: u32,
    block: u32,
}

/// Client-side copy of the epoch-stamped routing state.
struct ClientTable {
    epoch: u64,
    stripes: u32,
    failed_data: Vec<(u32, u32)>,
}

fn fetch_table(http_addr: &str) -> Result<ClientTable, String> {
    let body = http_request(http_addr, "GET", "/v1/route")?;
    let epoch = json_u64(&body, "epoch").ok_or("route reply missing epoch")?;
    let stripes = json_u64(&body, "stripes").ok_or("route reply missing stripes")? as u32;
    let k = json_u64(&body, "k").ok_or("route reply missing k")? as u32;
    let failed_data = json_pairs(&body, "failed_blocks")
        .into_iter()
        .filter(|&(_, b)| b < k)
        .collect();
    Ok(ClientTable { epoch, stripes, failed_data })
}

/// Minimal one-shot HTTP client (the control API is `Connection: close`).
pub fn http_request(addr: &str, method: &str, path_query: &str) -> Result<String, String> {
    let mut s = std::net::TcpStream::connect(addr).map_err(|e| e.to_string())?;
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(Duration::from_secs(5))).ok();
    let req = format!("{method} {path_query} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    s.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let mut raw = String::new();
    s.read_to_string(&mut raw).map_err(|e| e.to_string())?;
    match raw.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Err(format!("malformed HTTP reply from {addr}")),
    }
}

/// Run the closed loop and return the aggregate report. Also emits the
/// `BENCH_serve.json` artifact when `UNILRC_BENCH_JSON` is set.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenReport, String> {
    let mixes = WorkloadSpec::tenant_mixes();
    let deadline = Instant::now() + cfg.duration;
    let mut handles = Vec::new();
    for i in 0..cfg.sessions {
        let tenant = (i % mixes.len()) as u8;
        let spec = mixes[i % mixes.len()];
        let data_addr = cfg.data_addr.clone();
        let http_addr = cfg.http_addr.clone();
        let pipeline = cfg.pipeline.max(1);
        let seed = cfg.seed.wrapping_add(i as u64 * 7919);
        handles.push(std::thread::spawn(move || {
            run_session(&data_addr, &http_addr, tenant, spec, pipeline, seed, deadline)
        }));
    }

    // Mid-run topology event: the live migration wave every in-flight
    // epoch-stamped request must survive via redirect + retry.
    if let Some(at) = cfg.topology_event_at {
        std::thread::sleep(at.min(cfg.duration));
        http_request(&cfg.http_addr, "POST", "/v1/topology?event=add_node&cluster=0")?;
    }

    let mut report = LoadgenReport::default();
    let mut latencies: Vec<f64> = Vec::new();
    for h in handles {
        let out = h.join().map_err(|_| "loadgen session panicked".to_string())?;
        report.requests += out.requests;
        report.ok += out.ok;
        report.repairs += out.repairs;
        report.stale_redirects += out.stale;
        report.unrecovered_redirects += out.unrecovered;
        report.protocol_errors += out.protocol_errors;
        report.op_errors += out.op_errors;
        report.in_order_violations += out.in_order_violations;
        latencies.extend(out.latencies_ms);
    }
    // A dead or unreachable server yields zero completed operations; the old
    // behavior reported p99 = 0.0 ms, which sailed under any `--assert-p99-ms`
    // gate. Fail loudly instead — and before emitting bench rows, so CI never
    // records a vacuous all-zero latency artifact.
    if report.ok == 0 || latencies.is_empty() {
        return Err(format!(
            "loadgen completed zero successful operations \
             ({} requests, {} protocol errors, {} op errors) — server dead or unreachable",
            report.requests, report.protocol_errors, report.op_errors
        ));
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let pct = |q| crate::stats::percentile_sorted(&latencies, q).expect("non-empty checked above");
    report.p50_ms = pct(0.50);
    report.p95_ms = pct(0.95);
    report.p99_ms = pct(0.99);

    let mut json = JsonReport::new("serve");
    json.meta("sessions", &cfg.sessions.to_string());
    json.meta("pipeline", &cfg.pipeline.to_string());
    json.meta("duration_s", &cfg.duration.as_secs_f64().to_string());
    // Value rows are lower-is-better under tools/bench_compare.py, so the
    // artifact carries latency percentiles and must-be-zero invariant
    // counters — never throughput.
    json.add_value("get_p50_ms", report.p50_ms, "ms");
    json.add_value("get_p95_ms", report.p95_ms, "ms");
    json.add_value("get_p99_ms", report.p99_ms, "ms");
    json.add_value("protocol_errors", report.protocol_errors as f64, "count");
    json.add_value("unrecovered_redirects", report.unrecovered_redirects as f64, "count");
    json.add_value("in_order_violations", report.in_order_violations as f64, "count");
    json.write_if_requested();

    Ok(report)
}

fn run_session(
    data_addr: &str,
    http_addr: &str,
    tenant: u8,
    spec: WorkloadSpec,
    pipeline: usize,
    seed: u64,
    deadline: Instant,
) -> SessionOutcome {
    let mut out = SessionOutcome::default();
    let Ok(mut table) = fetch_table(http_addr) else {
        out.protocol_errors += 1;
        return out;
    };
    let stream = match std::net::TcpStream::connect(data_addr) {
        Ok(s) => s,
        Err(_) => {
            out.protocol_errors += 1;
            return out;
        }
    };
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
    let mut stream = stream;

    let mut prng = Prng::new(seed);
    let mut next_id: u64 = 1;
    let mut batch_no: u64 = 0;
    while Instant::now() < deadline {
        batch_no += 1;
        // Foreground first; at most one repair, and always last, so its
        // QoS wait never queues in front of a measured read.
        let mut specs: Vec<OpSpec> = Vec::with_capacity(pipeline);
        for slot in 0..pipeline {
            let stripe = prng.gen_range(table.stripes as usize) as u32;
            if slot == 0 && batch_no % 3 == 0 && !table.failed_data.is_empty() {
                let (s, b) = table.failed_data[prng.gen_range(table.failed_data.len())];
                specs.push(OpSpec { op: OpKind::DegradedRead, stripe: s, block: b });
            } else {
                let size = spec.draw(&mut prng) as u32;
                specs.push(OpSpec { op: OpKind::Get, stripe, block: size });
            }
        }
        if tenant == 0 && batch_no % 4 == 0 && !table.failed_data.is_empty() {
            let (s, b) = table.failed_data[prng.gen_range(table.failed_data.len())];
            specs.push(OpSpec { op: OpKind::Repair, stripe: s, block: b });
        }

        // Send the batch; on StaleEpoch, refresh the table and retry
        // just the redirected ops (bounded).
        let mut pending = specs;
        let mut attempts = 0;
        while !pending.is_empty() && attempts < 5 {
            attempts += 1;
            match exchange_batch(&mut stream, &mut out, tenant, table.epoch, &pending, &mut next_id)
            {
                Ok(stale) => {
                    if stale.is_empty() {
                        pending.clear();
                    } else {
                        out.stale += stale.len() as u64;
                        match fetch_table(http_addr) {
                            Ok(t) => table = t,
                            Err(_) => {
                                out.protocol_errors += 1;
                                out.unrecovered += stale.len() as u64;
                                return out;
                            }
                        }
                        // Re-validate degraded/repair targets against the
                        // refreshed failure view; downgrade vanished ones.
                        pending = stale
                            .into_iter()
                            .map(|s| {
                                if s.op != OpKind::Get
                                    && !table.failed_data.contains(&(s.stripe, s.block))
                                {
                                    OpSpec { op: OpKind::Get, stripe: s.stripe, block: 1 }
                                } else {
                                    s
                                }
                            })
                            .collect();
                    }
                }
                Err(_) => {
                    out.protocol_errors += 1;
                    return out;
                }
            }
        }
        out.unrecovered += pending.len() as u64;
    }
    out
}

/// Ship one pipelined batch (single coalesced write), then read exactly
/// one in-order response per request. Returns the specs that were
/// answered `StaleEpoch` and need a retry under a refreshed table.
fn exchange_batch(
    stream: &mut std::net::TcpStream,
    out: &mut SessionOutcome,
    tenant: u8,
    epoch: u64,
    specs: &[OpSpec],
    next_id: &mut u64,
) -> Result<Vec<OpSpec>, String> {
    let mut wire = Vec::with_capacity(specs.len() * 34);
    let mut ids = Vec::with_capacity(specs.len());
    for s in specs {
        let id = *next_id;
        *next_id += 1;
        ids.push(id);
        wire.extend_from_slice(
            &Request { id, tenant, op: s.op, epoch, stripe: s.stripe, block: s.block }.encode(),
        );
    }
    let t0 = Instant::now();
    stream.write_all(&wire).map_err(|e| e.to_string())?;
    out.requests += specs.len() as u64;

    let mut stale = Vec::new();
    let mut acc: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let mut got = 0usize;
    while got < specs.len() {
        let frame = loop {
            match take_frame(&acc)? {
                Some((payload, used)) => {
                    let resp = Response::decode(payload)?;
                    acc.drain(..used);
                    break resp;
                }
                None => {
                    let n = stream.read(&mut chunk).map_err(|e| e.to_string())?;
                    if n == 0 {
                        return Err("server closed mid-batch".to_string());
                    }
                    acc.extend_from_slice(&chunk[..n]);
                }
            }
        };
        let spec = specs[got];
        if frame.id() != ids[got] {
            out.in_order_violations += 1;
        }
        match frame {
            Response::Ok { .. } => {
                out.ok += 1;
                if spec.op == OpKind::Repair {
                    out.repairs += 1;
                } else {
                    out.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            Response::StaleEpoch { .. } => stale.push(spec),
            Response::Error { .. } => out.op_errors += 1,
        }
        got += 1;
    }
    Ok(stale)
}

#[cfg(test)]
mod tests {
    use crate::stats::percentile_sorted;

    #[test]
    fn percentiles_pick_sensible_ranks() {
        // pins the nearest-rank semantics the report fields rely on, now
        // served by the shared crate::stats implementation
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile_sorted(&xs, 0.50), Some(51.0));
        assert_eq!(percentile_sorted(&xs, 0.99), Some(99.0));
        assert_eq!(percentile_sorted(&[], 0.99), None);
        assert_eq!(percentile_sorted(&[7.5], 0.5), Some(7.5));
    }
}
