//! The serving plane: a tokio-based front end that turns the
//! virtual-clock experiment driver into a system under load over real
//! sockets.
//!
//! Three pieces (ROADMAP item 1):
//!
//! * **Pipelined client sessions** ([`session`]) speaking the
//!   length-prefixed binary protocol of [`protocol`]: a session may keep
//!   many requests in flight; responses come back strictly in request
//!   order, coalesced into batched socket writes by a dedicated writer
//!   task (the undermoon `CircularBufWriter` discipline — one
//!   `write`+`flush` per wakeup, not per response).
//! * **Per-tenant admission with QoS** ([`admission`]): a bounded
//!   in-flight window per tenant, and background repair traffic both
//!   yields to active foreground reads and pays a token bucket — the
//!   same discipline PR 7 applies to migration bandwidth.
//! * **Epoch-versioned metadata** ([`epoch`], [`http`]): every routing
//!   mutation in the coordinator bumps a metadata epoch (durable via
//!   `WalRecord::Epoch` + the v2 manifest); clients cache epoch-stamped
//!   routing tables and stamp every request. A request carrying a stale
//!   epoch is answered with a typed `StaleEpoch` redirect instead of
//!   being served against routing the client no longer holds — which is
//!   what makes reads provably safe across live migration waves.
//!
//! [`server`] wires these to a [`crate::coordinator::Dss`] behind a
//! mutex (operations advance the shared virtual clock; wall-clock tail
//! latency is measured by the closed-loop [`loadgen`]), plus an
//! HTTP/JSON control API for cluster metadata, topology events, and
//! failure reporting.

pub mod admission;
pub mod epoch;
pub mod http;
pub mod loadgen;
pub mod protocol;
pub mod server;
pub mod session;

pub use admission::{Admission, AdmissionConfig};
pub use epoch::RoutingTable;
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use protocol::{OpKind, Request, Response, MAX_FRAME};
pub use server::{bind, ServeConfig, ServeState, ServerHandle};
