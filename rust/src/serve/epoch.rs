//! Epoch-stamped routing tables.
//!
//! A [`RoutingTable`] is what the control API hands a proxy/client: a
//! consistent snapshot of where every block lives, which blocks are
//! failed, and the metadata epoch the snapshot was taken at. Clients
//! stamp data-plane requests with that epoch; the server compares it
//! against the live [`crate::coordinator::Dss::epoch`] and answers
//! `StaleEpoch` on mismatch, so a client can never act on routing that
//! a migration commit, failure, or ingest has since invalidated.

use crate::coordinator::Dss;

/// A consistent, epoch-stamped snapshot of the cluster's routing state.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    /// Epoch at capture; any later routing mutation makes it stale.
    pub epoch: u64,
    pub stripes: usize,
    /// Data blocks per stripe (`k`).
    pub k: usize,
    /// Total blocks per stripe (`n`).
    pub n: usize,
    /// `node_of[stripe][block]` — current home of every block.
    pub node_of: Vec<Vec<u32>>,
    /// `(stripe, block)` pairs currently unreadable (failed node) —
    /// the targets degraded reads and repairs are aimed at.
    pub failed_blocks: Vec<(u32, u32)>,
    /// Blocks mid-migration (`BlockState::Migrating`), still served
    /// from their source until commit.
    pub migrating: usize,
}

impl RoutingTable {
    /// Capture the current table. Callers hold the server's Dss lock,
    /// so the epoch and the routing rows are mutually consistent.
    pub fn capture(dss: &Dss) -> RoutingTable {
        let meta = dss.metadata();
        let stripes = meta.stripe_count();
        let n = dss.code.n();
        let mut node_of = Vec::with_capacity(stripes);
        let mut failed_blocks = Vec::new();
        for s in 0..stripes {
            let mut row = Vec::with_capacity(n);
            for b in 0..n {
                row.push(meta.node_of(s, b) as u32);
            }
            node_of.push(row);
            for b in dss.failed_blocks(s) {
                failed_blocks.push((s as u32, b as u32));
            }
        }
        RoutingTable {
            epoch: dss.epoch(),
            stripes,
            k: dss.code.k(),
            n,
            node_of,
            failed_blocks,
            migrating: meta.block_map().migrating_count(),
        }
    }

    /// Failed *data* blocks only — valid degraded-read targets.
    pub fn failed_data_blocks(&self) -> Vec<(u32, u32)> {
        self.failed_blocks.iter().copied().filter(|&(_, b)| (b as usize) < self.k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeFamily;
    use crate::experiments::{build_dss, ExpConfig};
    use crate::prng::Prng;

    fn dss() -> Dss {
        let cfg = ExpConfig {
            block_size: 4096,
            stripes: 2,
            time_compute: false,
            ..ExpConfig::default()
        };
        let mut dss = build_dss(CodeFamily::UniLrc, &cfg);
        let mut prng = Prng::new(cfg.seed);
        dss.ingest_random_stripes(cfg.stripes, &mut prng).unwrap();
        dss
    }

    #[test]
    fn capture_is_consistent_with_the_live_epoch() {
        let mut dss = dss();
        let t0 = RoutingTable::capture(&dss);
        assert_eq!(t0.epoch, dss.epoch());
        assert_eq!(t0.stripes, 2);
        assert_eq!(t0.node_of.len(), 2);
        assert!(t0.failed_blocks.is_empty());

        // A failure bumps the epoch and shows up in the next capture.
        let victim = dss.metadata().node_of(0, 0);
        dss.fail_node(victim);
        let t1 = RoutingTable::capture(&dss);
        assert!(t1.epoch > t0.epoch);
        assert!(t1.failed_blocks.contains(&(0, 0)));
        assert!(t1.failed_data_blocks().iter().all(|&(_, b)| (b as usize) < t1.k));
    }

    #[test]
    fn every_routing_mutation_bumps_the_epoch() {
        let mut dss = dss();
        let mut last = dss.epoch();
        let victim = dss.metadata().node_of(1, 1);
        dss.fail_node(victim);
        assert!(dss.epoch() > last, "fail_node must bump");
        last = dss.epoch();
        dss.heal_node(victim);
        assert!(dss.epoch() > last, "heal_node must bump");
        last = dss.epoch();
        let mut prng = Prng::new(7);
        dss.ingest_random_stripes(1, &mut prng).unwrap();
        assert!(dss.epoch() > last, "ingest must bump");
    }
}
