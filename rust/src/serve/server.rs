//! Server wiring: shared state, listeners, and the migration pump.
//!
//! The server owns one [`Dss`] behind a mutex — operations advance its
//! shared virtual clock exactly as the experiment driver does — plus a
//! lock-free **epoch mirror** ([`ServeState::epoch`]) that sessions
//! consult to answer `StaleEpoch` without taking the coordinator lock.
//! The mirror is refreshed (under the Dss lock, so it can only lag,
//! never lead) after every mutation the serving plane itself performs;
//! the authoritative re-check in [`crate::serve::session::handle`]
//! happens under the lock.

use crate::codes::CodeFamily;
use crate::coordinator::{Dss, DurabilityOptions, MigrationError};
use crate::experiments::{build_dss, ExpConfig};
use crate::placement::TopologyEvent;
use crate::prng::Prng;
use crate::serve::admission::{Admission, AdmissionConfig};
use crate::serve::{http, session};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;
use tokio::net::TcpListener;
use tokio::task::JoinHandle;

/// Serving-plane configuration (CLI flags map 1:1 onto this).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Data-plane listen address (`0` port = ephemeral, for tests).
    pub data_addr: String,
    /// Control-plane (HTTP/JSON) listen address.
    pub http_addr: String,
    pub stripes: usize,
    pub block_size: usize,
    pub seed: u64,
    /// Nodes to fail at boot so degraded reads and repairs have targets.
    pub fail_nodes: usize,
    pub admission: AdmissionConfig,
    /// Enable the durable coordinator under this directory.
    pub wal_dir: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            data_addr: "127.0.0.1:0".to_string(),
            http_addr: "127.0.0.1:0".to_string(),
            stripes: 4,
            block_size: 64 * 1024,
            seed: 42,
            fail_nodes: 1,
            admission: AdmissionConfig::default(),
            wal_dir: None,
        }
    }
}

/// Monotonic serving counters, exported via `GET /v1/stats`.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub sessions: AtomicU64,
    pub requests: AtomicU64,
    pub responses_ok: AtomicU64,
    pub stale_redirects: AtomicU64,
    pub protocol_errors: AtomicU64,
    pub op_errors: AtomicU64,
    /// Response frames written (≥ flushes: the gap is the batching win).
    pub frames_out: AtomicU64,
    /// Batched socket flushes issued by session writer tasks.
    pub flushes: AtomicU64,
}

/// State shared by every session, the control API, and the pump.
pub struct ServeState {
    dss: Mutex<Dss>,
    /// Lock-free mirror of [`Dss::epoch`] for the fast staleness gate.
    pub epoch: AtomicU64,
    pub admission: Admission,
    pub stats: ServeStats,
    pub shutdown: AtomicBool,
    /// True while a migration pump task is running (at most one).
    pump_active: AtomicBool,
    /// Cached so sessions can size admission without the Dss lock.
    pub block_size: usize,
}

impl ServeState {
    /// Lock the coordinator (poison-tolerant: a panicked session must
    /// not wedge the server).
    pub fn dss(&self) -> MutexGuard<'_, Dss> {
        self.dss.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Refresh the epoch mirror from the live coordinator. Callers hold
    /// the Dss lock (enforced by the `&Dss` borrow), so the mirror is
    /// never published ahead of the state it describes.
    pub fn sync_epoch(&self, dss: &Dss) {
        self.epoch.store(dss.epoch(), Ordering::Release);
    }
}

/// A bound, running server: listener addresses plus shutdown control.
pub struct ServerHandle {
    state: Arc<ServeState>,
    data_addr: SocketAddr,
    http_addr: SocketAddr,
    tasks: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn data_addr(&self) -> SocketAddr {
        self.data_addr
    }

    pub fn http_addr(&self) -> SocketAddr {
        self.http_addr
    }

    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Request shutdown and poke both accept loops awake.
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::Release);
        let _ = std::net::TcpStream::connect(self.data_addr);
        let _ = std::net::TcpStream::connect(self.http_addr);
    }

    /// Wait for the accept loops to exit (after [`ServerHandle::shutdown`]).
    pub async fn wait(self) {
        for t in self.tasks {
            let _ = t.await;
        }
    }
}

/// Build the coordinator, bind both planes, and start accepting.
pub async fn bind(cfg: ServeConfig) -> anyhow::Result<ServerHandle> {
    let exp = ExpConfig {
        block_size: cfg.block_size,
        stripes: cfg.stripes,
        seed: cfg.seed,
        time_compute: false,
        ..ExpConfig::default()
    };
    let mut dss = build_dss(CodeFamily::UniLrc, &exp);
    let mut prng = Prng::new(cfg.seed);
    dss.ingest_random_stripes(cfg.stripes, &mut prng)?;
    if let Some(dir) = &cfg.wal_dir {
        dss.enable_durability(dir, DurabilityOptions::default())?;
    }
    for i in 0..cfg.fail_nodes {
        let node = dss.metadata().node_of(i % cfg.stripes.max(1), 0);
        if !dss.failed_nodes().contains(&node) {
            dss.fail_node(node);
        }
    }
    let epoch0 = dss.epoch();

    let data = TcpListener::bind(&cfg.data_addr).await?;
    let http = TcpListener::bind(&cfg.http_addr).await?;
    let data_addr = data.local_addr()?;
    let http_addr = http.local_addr()?;

    let state = Arc::new(ServeState {
        dss: Mutex::new(dss),
        epoch: AtomicU64::new(epoch0),
        admission: Admission::new(cfg.admission),
        stats: ServeStats::default(),
        shutdown: AtomicBool::new(false),
        pump_active: AtomicBool::new(false),
        block_size: cfg.block_size,
    });

    let s_data = Arc::clone(&state);
    let accept_data = tokio::spawn(async move {
        loop {
            let Ok((stream, _)) = data.accept().await else { break };
            if s_data.shutdown.load(Ordering::Acquire) {
                break;
            }
            s_data.stats.sessions.fetch_add(1, Ordering::Relaxed);
            let s = Arc::clone(&s_data);
            tokio::spawn(async move {
                session::run_session(stream, s).await;
            });
        }
    });
    let s_http = Arc::clone(&state);
    let accept_http = tokio::spawn(async move {
        loop {
            let Ok((stream, _)) = http.accept().await else { break };
            if s_http.shutdown.load(Ordering::Acquire) {
                break;
            }
            let s = Arc::clone(&s_http);
            tokio::spawn(async move {
                http::run_http(stream, s).await;
            });
        }
    });

    Ok(ServerHandle { state, data_addr, http_addr, tasks: vec![accept_data, accept_http] })
}

/// Submit a topology event (control API / tests): admission bumps the
/// epoch immediately — in-flight stale requests start redirecting right
/// away — and a background pump drives the planned moves to completion.
/// Returns `(event_id, epoch_after_admission)`.
pub fn submit_topology(
    state: &Arc<ServeState>,
    ev: TopologyEvent,
) -> Result<(u32, u64), MigrationError> {
    let (id, epoch) = {
        let mut dss = state.dss();
        let id = dss.submit_topology_event(ev)?;
        state.sync_epoch(&dss);
        (id, dss.epoch())
    };
    spawn_pump(state);
    Ok((id, epoch))
}

/// Start the migration pump unless one is already running. Each round
/// drives a few moves on the virtual clock, republishes the epoch
/// mirror, and yields, so foreground sessions interleave with the wave
/// instead of stalling behind one long lock hold.
pub fn spawn_pump(state: &Arc<ServeState>) {
    if state.pump_active.swap(true, Ordering::AcqRel) {
        return;
    }
    let s = Arc::clone(state);
    tokio::spawn(async move {
        loop {
            if s.shutdown.load(Ordering::Acquire) {
                break;
            }
            let (in_flight, parked) = {
                let mut dss = s.dss();
                if dss.online_in_flight() > 0 {
                    let until = dss.clock() + 3600.0;
                    if dss.pump_migrations(until, 4).is_err() {
                        s.stats.op_errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                s.sync_epoch(&dss);
                (dss.online_in_flight(), dss.parked_events().len())
            };
            if in_flight == 0 || parked == in_flight {
                break;
            }
            tokio::time::sleep(Duration::from_millis(2)).await;
        }
        s.pump_active.store(false, Ordering::Release);
    });
}
