//! Bounded per-tenant admission with foreground/background QoS.
//!
//! Two rules, enforced before a request may touch the coordinator:
//!
//! 1. **Bounded in-flight window per tenant** — a tenant may hold at
//!    most `per_tenant` operations in flight; further requests from
//!    that tenant block (backpressure through the pipelined session,
//!    which stops reading its socket) instead of growing an unbounded
//!    queue.
//! 2. **Foreground preempts background** — a background op (repair)
//!    only starts while no foreground read is active, and additionally
//!    pays its bytes into a [`TokenBucket`] (the PR 7 migration
//!    throttle, here on the wall clock), so a repair storm can neither
//!    cut ahead of reads nor saturate the coordinator between them.
//!
//! Release is RAII: the permit returned by [`Admission::acquire`]
//! restores the window and wakes waiters on drop, so an op that errors
//! can never leak its slot.

use crate::sim::TokenBucket;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Tenant ids are a small fixed namespace (the three `WorkloadSpec`
/// mixes plus headroom).
pub const MAX_TENANTS: usize = 8;

#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// In-flight cap per tenant (rule 1).
    pub per_tenant: usize,
    /// Background repair budget, bytes/second (rule 2).
    pub repair_rate_bps: f64,
    /// Background burst allowance, bytes.
    pub repair_burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        // 64 MiB/s with one-block bursts: repairs flow steadily while
        // foreground is idle but never monopolize the coordinator.
        AdmissionConfig {
            per_tenant: 32,
            repair_rate_bps: 64.0 * 1024.0 * 1024.0,
            repair_burst: 1024.0 * 1024.0,
        }
    }
}

struct Inner {
    inflight: [usize; MAX_TENANTS],
    /// Active foreground ops — background admission waits for zero.
    foreground: usize,
    bucket: TokenBucket,
}

/// Shared admission state; one per server.
pub struct Admission {
    inner: Mutex<Inner>,
    cv: Condvar,
    t0: Instant,
    cfg: AdmissionConfig,
    /// Foreground ops admitted.
    pub admitted_fg: AtomicU64,
    /// Background ops admitted.
    pub admitted_bg: AtomicU64,
    /// Background admissions that had to wait (preemption or tokens).
    pub bg_waits: AtomicU64,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission {
            inner: Mutex::new(Inner {
                inflight: [0; MAX_TENANTS],
                foreground: 0,
                bucket: TokenBucket::new(cfg.repair_rate_bps, cfg.repair_burst),
            }),
            cv: Condvar::new(),
            t0: Instant::now(),
            cfg,
            admitted_fg: AtomicU64::new(0),
            admitted_bg: AtomicU64::new(0),
            bg_waits: AtomicU64::new(0),
        }
    }

    /// Block until `tenant` has window and (for background ops) QoS
    /// clearance, then return the RAII permit.
    pub fn acquire(&self, tenant: u8, background: bool, bytes: usize) -> Permit<'_> {
        let tenant = tenant as usize % MAX_TENANTS;
        let mut waited = false;
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            let window_open = g.inflight[tenant] < self.cfg.per_tenant;
            let qos_clear = !background || g.foreground == 0;
            if window_open && qos_clear {
                break;
            }
            waited = waited || background;
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        g.inflight[tenant] += 1;
        if background {
            // Pay the token bucket on the wall clock; the deficit delay
            // is served *outside* the lock so foreground admission never
            // queues behind a throttled repair.
            let now = self.t0.elapsed().as_secs_f64();
            let at = g.bucket.acquire(now, bytes);
            drop(g);
            if at > now {
                waited = true;
                std::thread::sleep(Duration::from_secs_f64(at - now));
            }
            self.admitted_bg.fetch_add(1, Ordering::Relaxed);
            if waited {
                self.bg_waits.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            g.foreground += 1;
            drop(g);
            self.admitted_fg.fetch_add(1, Ordering::Relaxed);
        }
        Permit { admission: self, tenant, background }
    }
}

/// RAII admission slot: releases the tenant window (and the foreground
/// mark) and wakes waiters on drop.
pub struct Permit<'a> {
    admission: &'a Admission,
    tenant: usize,
    background: bool,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut g = self.admission.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.inflight[self.tenant] -= 1;
        if !self.background {
            g.foreground -= 1;
        }
        drop(g);
        self.admission.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn cfg(per_tenant: usize) -> AdmissionConfig {
        // Token budget effectively unthrottled so tests exercise the
        // window/preemption logic, not the sleep.
        AdmissionConfig { per_tenant, repair_rate_bps: 1e12, repair_burst: 1e12 }
    }

    #[test]
    fn per_tenant_window_blocks_and_releases() {
        let adm = Arc::new(Admission::new(cfg(1)));
        let p = adm.acquire(0, false, 0);
        // Same tenant blocks; a different tenant sails through.
        let other = adm.acquire(1, false, 0);
        drop(other);
        let adm2 = Arc::clone(&adm);
        let blocked = Arc::new(AtomicUsize::new(0));
        let blocked2 = Arc::clone(&blocked);
        let h = std::thread::spawn(move || {
            let _p = adm2.acquire(0, false, 0);
            blocked2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(blocked.load(Ordering::SeqCst), 0, "window must block the second acquire");
        drop(p);
        h.join().unwrap();
        assert_eq!(blocked.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn background_yields_to_active_foreground() {
        let adm = Arc::new(Admission::new(cfg(4)));
        let fg = adm.acquire(0, false, 0);
        let adm2 = Arc::clone(&adm);
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = Arc::clone(&done);
        let h = std::thread::spawn(move || {
            let _p = adm2.acquire(2, true, 4096);
            done2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(done.load(Ordering::SeqCst), 0, "repair must wait for the foreground read");
        drop(fg);
        h.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
        assert_eq!(adm.admitted_bg.load(Ordering::Relaxed), 1);
        assert!(adm.bg_waits.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn throttle_delays_background_bursts() {
        // 1 KiB/s with a 1-byte burst: the second 512-byte repair must
        // wait ~0.5s for the deficit to accrue.
        let adm = Admission::new(AdmissionConfig {
            per_tenant: 8,
            repair_rate_bps: 1024.0,
            repair_burst: 1.0,
        });
        let t = Instant::now();
        drop(adm.acquire(2, true, 512));
        drop(adm.acquire(2, true, 512));
        assert!(t.elapsed() >= Duration::from_millis(400), "token deficit must delay");
    }
}
