//! Length-prefixed binary wire protocol for the data plane.
//!
//! Framing: `[len: u32 LE][payload]`, `0 < len ≤ MAX_FRAME`. Payloads
//! reuse the coordinator manifest's little-endian codec helpers, so the
//! whole repo has exactly one binary-encoding idiom.
//!
//! Request payload: `id u64 · tenant u8 · op u8 · epoch u64 ·
//! stripe u32 · block u32`. For [`OpKind::Get`] the `block` field
//! carries the *object size in data blocks* (the `WorkloadSpec` draw),
//! not a block index — a get reads that many data blocks of the stripe,
//! degraded ones transparently repaired on the read path. For
//! `DegradedRead`/`Repair` it is the target block index.
//!
//! Response payload: tag `u8`, then per tag:
//! * `0` Ok: `id u64 · epoch u64 · latency_us u64 · bytes u64` —
//!   `latency_us` is the *virtual-clock* service latency; wall latency
//!   is the client's to measure.
//! * `1` StaleEpoch: `id u64 · current u64` — the request's epoch no
//!   longer matches; refresh the routing table and retry.
//! * `2` Error: `id u64 · detail str` — typed protocol-level failure.

use crate::coordinator::manifest::{put_u32, put_u64, Cursor};

/// Maximum frame payload accepted by either side. Requests are ~26
/// bytes and responses ~33; anything near the cap is a corrupt or
/// hostile length prefix and is rejected before allocation.
pub const MAX_FRAME: usize = 1 << 16;

/// Data-plane operation kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Read the first `block` data blocks of `stripe` (object read).
    Get,
    /// Degraded read of one failed data block.
    DegradedRead,
    /// Background repair: reconstruct one failed block onto a spare.
    Repair,
}

impl OpKind {
    pub fn tag(self) -> u8 {
        match self {
            OpKind::Get => 1,
            OpKind::DegradedRead => 2,
            OpKind::Repair => 3,
        }
    }

    pub fn from_tag(tag: u8) -> Option<OpKind> {
        match tag {
            1 => Some(OpKind::Get),
            2 => Some(OpKind::DegradedRead),
            3 => Some(OpKind::Repair),
            _ => None,
        }
    }

    /// Background ops yield to foreground reads in admission.
    pub fn is_background(self) -> bool {
        matches!(self, OpKind::Repair)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Session-scoped correlation id; responses echo it and a pipelined
    /// session answers ids strictly in request order.
    pub id: u64,
    pub tenant: u8,
    pub op: OpKind,
    /// Routing-table epoch the client holds (see module docs).
    pub epoch: u64,
    pub stripe: u32,
    pub block: u32,
}

impl Request {
    /// Encode as one frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(30);
        put_u64(&mut p, self.id);
        p.push(self.tenant);
        p.push(self.op.tag());
        put_u64(&mut p, self.epoch);
        put_u32(&mut p, self.stripe);
        put_u32(&mut p, self.block);
        frame(p)
    }

    /// Decode one frame payload (length prefix already stripped).
    pub fn decode(payload: &[u8]) -> Result<Request, String> {
        let mut cur = Cursor::new(payload);
        let id = cur.u64()?;
        let tenant = cur.u8()?;
        let op = OpKind::from_tag(cur.u8()?).ok_or_else(|| "unknown op tag".to_string())?;
        let epoch = cur.u64()?;
        let stripe = cur.u32()?;
        let block = cur.u32()?;
        cur.done()?;
        Ok(Request { id, tenant, op, epoch, stripe, block })
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Ok { id: u64, epoch: u64, latency_us: u64, bytes: u64 },
    StaleEpoch { id: u64, current: u64 },
    Error { id: u64, detail: String },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Ok { id, .. } | Response::StaleEpoch { id, .. } | Response::Error { id, .. } => {
                *id
            }
        }
    }

    /// Encode as one frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(40);
        match self {
            Response::Ok { id, epoch, latency_us, bytes } => {
                p.push(0);
                put_u64(&mut p, *id);
                put_u64(&mut p, *epoch);
                put_u64(&mut p, *latency_us);
                put_u64(&mut p, *bytes);
            }
            Response::StaleEpoch { id, current } => {
                p.push(1);
                put_u64(&mut p, *id);
                put_u64(&mut p, *current);
            }
            Response::Error { id, detail } => {
                p.push(2);
                put_u64(&mut p, *id);
                put_u32(&mut p, detail.len() as u32);
                p.extend_from_slice(detail.as_bytes());
            }
        }
        frame(p)
    }

    /// Decode one frame payload (length prefix already stripped).
    pub fn decode(payload: &[u8]) -> Result<Response, String> {
        let mut cur = Cursor::new(payload);
        let resp = match cur.u8()? {
            0 => Response::Ok {
                id: cur.u64()?,
                epoch: cur.u64()?,
                latency_us: cur.u64()?,
                bytes: cur.u64()?,
            },
            1 => Response::StaleEpoch { id: cur.u64()?, current: cur.u64()? },
            2 => Response::Error { id: cur.u64()?, detail: cur.str(MAX_FRAME)? },
            t => return Err(format!("unknown response tag {t}")),
        };
        cur.done()?;
        Ok(resp)
    }
}

/// Prefix `payload` with its little-endian u32 length.
fn frame(payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 4);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

/// Split one frame off the front of `buf`: `Ok(Some(payload))` when a
/// whole frame is buffered, `Ok(None)` when more bytes are needed.
pub fn take_frame(buf: &[u8]) -> Result<Option<(&[u8], usize)>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(format!("frame length {len} out of range"));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((&buf[4..4 + len], 4 + len)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let req =
            Request { id: 7, tenant: 2, op: OpKind::DegradedRead, epoch: 9, stripe: 3, block: 1 };
        let framed = req.encode();
        let (payload, used) = take_frame(&framed).unwrap().unwrap();
        assert_eq!(used, framed.len());
        assert_eq!(Request::decode(payload).unwrap(), req);
    }

    #[test]
    fn responses_round_trip() {
        for resp in [
            Response::Ok { id: 1, epoch: 4, latency_us: 1500, bytes: 262144 },
            Response::StaleEpoch { id: 2, current: 5 },
            Response::Error { id: 3, detail: "no such stripe".into() },
        ] {
            let framed = resp.encode();
            let (payload, _) = take_frame(&framed).unwrap().unwrap();
            assert_eq!(Response::decode(payload).unwrap(), resp);
        }
    }

    #[test]
    fn partial_and_hostile_frames() {
        let framed =
            Request { id: 1, tenant: 0, op: OpKind::Get, epoch: 1, stripe: 0, block: 4 }.encode();
        for cut in 0..framed.len() {
            assert!(take_frame(&framed[..cut]).unwrap().is_none(), "cut {cut} yielded a frame");
        }
        // zero / oversized length prefixes are rejected, not chased
        assert!(take_frame(&[0, 0, 0, 0, 9]).is_err());
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        assert!(take_frame(&huge).is_err());
    }

    #[test]
    fn pipelined_frames_split_cleanly() {
        let mut buf = Vec::new();
        for id in 0..5u64 {
            buf.extend_from_slice(
                &Request { id, tenant: 0, op: OpKind::Get, epoch: 1, stripe: 0, block: 1 }
                    .encode(),
            );
        }
        let mut seen = Vec::new();
        let mut pos = 0;
        while let Some((payload, used)) = take_frame(&buf[pos..]).unwrap() {
            seen.push(Request::decode(payload).unwrap().id);
            pos += used;
        }
        assert_eq!(pos, buf.len());
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bad_op_tag_is_typed_error() {
        let mut framed =
            Request { id: 1, tenant: 0, op: OpKind::Get, epoch: 1, stripe: 0, block: 1 }.encode();
        framed[4 + 9] = 99; // op tag sits after len(4) + id(8) + tenant(1)
        let (payload, _) = take_frame(&framed).unwrap().unwrap();
        assert!(Request::decode(payload).is_err());
    }
}
