//! Coordinator control API: hand-rolled HTTP/1.1 + JSON, one request
//! per connection (`Connection: close`), no external dependencies.
//!
//! Routes:
//!
//! * `GET /v1/epoch` — `{"epoch":N}`; the cheap poll clients use to
//!   refresh after a `StaleEpoch` redirect.
//! * `GET /v1/route` — the full epoch-stamped [`RoutingTable`].
//! * `GET /v1/cluster` — code/topology/failure summary.
//! * `GET /v1/stats` — serving counters + admission + migration state.
//! * `POST /v1/topology?event=add_node&cluster=C` (also `add_cluster`
//!   `&nodes=N`, `drain&node=N`, `decommission&cluster=C`) — submit a
//!   topology event; admission bumps the epoch and starts the pump.
//! * `POST /v1/failures?node=N[&heal=1]` — report a failure (or heal).
//!
//! The JSON emitters/parsers here are the deliberately tiny flat-object
//! subset the loadgen needs — not a general JSON library.

use crate::placement::TopologyEvent;
use crate::serve::epoch::RoutingTable;
use crate::serve::server::{submit_topology, ServeState};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use tokio::net::TcpStream;

/// Serve one control-API connection to completion.
pub async fn run_http(stream: TcpStream, state: Arc<ServeState>) {
    let (mut reader, mut writer) = stream.into_split();
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    while !header_complete(&buf) && buf.len() < 8192 {
        match reader.read(&mut chunk).await {
            Ok(0) | Err(_) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
        }
    }
    let text = String::from_utf8_lossy(&buf);
    let first = text.lines().next().unwrap_or("");
    let mut parts = first.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let (status, body) = route(&state, method, path, query);
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = writer.write_all(resp.as_bytes()).await;
    let _ = writer.flush().await;
    let _ = writer.shutdown_now();
}

fn header_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
}

fn route(state: &Arc<ServeState>, method: &str, path: &str, query: &str) -> (&'static str, String) {
    match (method, path) {
        ("GET", "/v1/epoch") => {
            let epoch = state.epoch.load(Ordering::Acquire);
            ("200 OK", format!("{{\"epoch\":{epoch}}}"))
        }
        ("GET", "/v1/route") => {
            let table = RoutingTable::capture(&state.dss());
            ("200 OK", route_json(&table))
        }
        ("GET", "/v1/cluster") => {
            let dss = state.dss();
            let mut failed: Vec<usize> = dss.failed_nodes().iter().copied().collect();
            failed.sort_unstable();
            let body = format!(
                "{{\"code\":\"{}\",\"k\":{},\"n\":{},\"clusters\":{},\"nodes\":{},\"stripes\":{},\"failed_nodes\":{},\"migrating\":{},\"epoch\":{}}}",
                dss.code.name(),
                dss.code.k(),
                dss.code.n(),
                dss.topo.clusters(),
                dss.topo.total_nodes(),
                dss.metadata().stripe_count(),
                json_usize_array(&failed),
                dss.metadata().block_map().migrating_count(),
                dss.epoch(),
            );
            ("200 OK", body)
        }
        ("GET", "/v1/stats") => {
            let (in_flight, parked, clock) = {
                let dss = state.dss();
                (dss.online_in_flight(), dss.parked_events().len(), dss.clock())
            };
            let s = &state.stats;
            let body = format!(
                "{{\"epoch\":{},\"sessions\":{},\"requests\":{},\"responses_ok\":{},\"stale_redirects\":{},\"protocol_errors\":{},\"op_errors\":{},\"frames_out\":{},\"flushes\":{},\"admitted_fg\":{},\"admitted_bg\":{},\"bg_waits\":{},\"online_in_flight\":{in_flight},\"parked_events\":{parked},\"virtual_clock\":{clock:.6}}}",
                state.epoch.load(Ordering::Acquire),
                s.sessions.load(Ordering::Relaxed),
                s.requests.load(Ordering::Relaxed),
                s.responses_ok.load(Ordering::Relaxed),
                s.stale_redirects.load(Ordering::Relaxed),
                s.protocol_errors.load(Ordering::Relaxed),
                s.op_errors.load(Ordering::Relaxed),
                s.frames_out.load(Ordering::Relaxed),
                s.flushes.load(Ordering::Relaxed),
                state.admission.admitted_fg.load(Ordering::Relaxed),
                state.admission.admitted_bg.load(Ordering::Relaxed),
                state.admission.bg_waits.load(Ordering::Relaxed),
            );
            ("200 OK", body)
        }
        ("POST", "/v1/topology") => match parse_topology_event(query) {
            Ok(ev) => match submit_topology(state, ev) {
                Ok((id, epoch)) => ("200 OK", format!("{{\"event_id\":{id},\"epoch\":{epoch}}}")),
                Err(e) => {
                    ("409 Conflict", format!("{{\"error\":\"{}\"}}", json_escape(&e.to_string())))
                }
            },
            Err(msg) => ("400 Bad Request", format!("{{\"error\":\"{msg}\"}}")),
        },
        ("POST", "/v1/failures") => {
            let Some(node) = query_param(query, "node").and_then(|v| v.parse::<usize>().ok())
            else {
                return ("400 Bad Request", "{\"error\":\"node=N required\"}".to_string());
            };
            let heal = query_param(query, "heal").is_some();
            let mut dss = state.dss();
            if node >= dss.topo.total_nodes() {
                return ("400 Bad Request", format!("{{\"error\":\"no such node {node}\"}}"));
            }
            if heal {
                dss.heal_node(node);
            } else {
                dss.fail_node(node);
            }
            state.sync_epoch(&dss);
            ("200 OK", format!("{{\"node\":{node},\"healed\":{heal},\"epoch\":{}}}", dss.epoch()))
        }
        _ => ("404 Not Found", "{\"error\":\"not found\"}".to_string()),
    }
}

fn parse_topology_event(query: &str) -> Result<TopologyEvent, String> {
    let kind = query_param(query, "event").ok_or("event=... required")?;
    let num = |key: &str| {
        query_param(query, key)
            .and_then(|v| v.parse::<usize>().ok())
            .ok_or_else(|| format!("{key}=N required for event={kind}"))
    };
    match kind {
        "add_node" => Ok(TopologyEvent::AddNode { cluster: num("cluster")? }),
        "add_cluster" => Ok(TopologyEvent::AddCluster { nodes: num("nodes")? }),
        "drain" => Ok(TopologyEvent::DrainNode { node: num("node")? }),
        "decommission" => Ok(TopologyEvent::DecommissionCluster { cluster: num("cluster")? }),
        other => Err(format!("unknown event '{other}'")),
    }
}

fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        (k == key).then_some(v)
    })
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_usize_array(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(","))
}

fn route_json(t: &RoutingTable) -> String {
    let rows: Vec<String> = t
        .node_of
        .iter()
        .map(|row| {
            let cells: Vec<String> = row.iter().map(|n| n.to_string()).collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    let failed: Vec<String> =
        t.failed_blocks.iter().map(|(s, b)| format!("[{s},{b}]")).collect();
    format!(
        "{{\"epoch\":{},\"stripes\":{},\"k\":{},\"n\":{},\"migrating\":{},\"node_of\":[{}],\"failed_blocks\":[{}]}}",
        t.epoch,
        t.stripes,
        t.k,
        t.n,
        t.migrating,
        rows.join(","),
        failed.join(","),
    )
}

// ------------------------------------------------------------------ JSON
// Tiny flat-JSON readers shared with the loadgen's HTTP client side.

/// Extract an unsigned integer field (`"key":123`) from a flat JSON
/// object. Not a general parser — exactly what `/v1/epoch`-style
/// replies need.
pub fn json_u64(body: &str, key: &str) -> Option<u64> {
    let needle = format!("\"{key}\":");
    let at = body.find(&needle)? + needle.len();
    let rest = &body[at..];
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Extract an array of `[a,b]` pairs (`"key":[[0,1],[2,3]]`).
pub fn json_pairs(body: &str, key: &str) -> Vec<(u32, u32)> {
    let needle = format!("\"{key}\":[");
    let Some(start) = body.find(&needle).map(|i| i + needle.len()) else {
        return Vec::new();
    };
    // Bound the enclosing array by bracket depth so a following array
    // field can never leak pairs into this one.
    let bytes = body.as_bytes();
    let mut depth = 1usize;
    let mut end = start;
    while end < bytes.len() && depth > 0 {
        match bytes[end] {
            b'[' => depth += 1,
            b']' => depth -= 1,
            _ => {}
        }
        end += 1;
    }
    let mut out = Vec::new();
    let mut rest = &body[start..end.saturating_sub(1).max(start)];
    while let Some(open) = rest.find('[') {
        let Some(close) = rest[open..].find(']').map(|i| open + i) else { break };
        let inner = &rest[open + 1..close];
        let mut nums = inner.split(',').filter_map(|x| x.trim().parse::<u32>().ok());
        if let (Some(a), Some(b)) = (nums.next(), nums.next()) {
            out.push((a, b));
        }
        rest = &rest[close + 1..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_params_parse() {
        assert_eq!(query_param("event=add_node&cluster=2", "cluster"), Some("2"));
        assert_eq!(query_param("event=add_node&cluster=2", "event"), Some("add_node"));
        assert_eq!(query_param("heal", "heal"), Some(""));
        assert_eq!(query_param("a=1", "b"), None);
    }

    #[test]
    fn topology_events_parse() {
        assert_eq!(
            parse_topology_event("event=add_node&cluster=3").unwrap(),
            TopologyEvent::AddNode { cluster: 3 }
        );
        assert_eq!(
            parse_topology_event("event=add_cluster&nodes=4").unwrap(),
            TopologyEvent::AddCluster { nodes: 4 }
        );
        assert_eq!(
            parse_topology_event("event=drain&node=9").unwrap(),
            TopologyEvent::DrainNode { node: 9 }
        );
        assert!(parse_topology_event("event=warp").is_err());
        assert!(parse_topology_event("event=add_node").is_err());
    }

    #[test]
    fn flat_json_readers() {
        let body = "{\"epoch\":41,\"stripes\":2,\"failed_blocks\":[[0,3],[1,7]],\"node_of\":[[1,2],[3,4]]}";
        assert_eq!(json_u64(body, "epoch"), Some(41));
        assert_eq!(json_u64(body, "stripes"), Some(2));
        assert_eq!(json_u64(body, "missing"), None);
        assert_eq!(json_pairs(body, "failed_blocks"), vec![(0, 3), (1, 7)]);
        assert_eq!(json_pairs("{\"failed_blocks\":[]}", "failed_blocks"), Vec::new());
    }
}
