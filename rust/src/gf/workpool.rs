//! Persistent GF worker pool — the executor behind every striped and
//! batched coding operation.
//!
//! The first engine iteration spawned scoped threads on *every*
//! `matmul_blocks` / `fold_blocks` call; the ~tens of µs of spawn + join
//! per call capped parallel wins to multi-MiB blocks and serialized
//! multi-stripe events stripe by stripe. [`WorkPool`] replaces that with
//! long-lived workers and a shared FIFO task queue:
//!
//! * workers are spawned once (sized with the engine's `--gf-threads` /
//!   `UNILRC_GF_THREADS` knob) and park on a condvar when idle;
//! * [`WorkPool::scope`] opens a [`BatchScope`] into which any number of
//!   tasks borrowing caller data can be submitted — a per-scope completion
//!   latch makes the borrow sound (the scope cannot return before every
//!   task ran), the same contract `std::thread::scope` provides without
//!   the per-call spawn;
//! * the scoping thread *helps drain the queue* while it waits, so a
//!   worker that opens a nested scope (e.g. a batched repair whose combine
//!   stripes a large block) can never deadlock: every waiter is also an
//!   executor;
//! * dropping the pool flags shutdown, wakes everyone, and joins the
//!   workers — engines (and their pools) constructed in tests come and go
//!   without leaking threads (`tests/workpool.rs` asserts this).
//!
//! Task panics are caught on the worker, recorded on the latch, and
//! re-raised on the scoping thread once the batch has fully settled.
//!
//! Two memory-system extensions ride on the pool:
//!
//! * **pinning** ([`WorkPool::with_pinning`]): each worker optionally pins
//!   itself to a distinct CPU (package-major plan from `gf/topo.rs`) so a
//!   stripe's lanes stay within one socket's LLC domain;
//! * **idle ticks**: worker 0 wakes on a short timeout when the queue is
//!   empty and runs the process-wide [idle hooks](add_idle_hook) —
//!   housekeeping like proactive decode-plan refresh happens on otherwise
//!   wasted worker time, throttled so an idle pool costs ~nothing.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// A queued unit of work (lifetime-erased; see [`BatchScope::submit`]).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// How often an idle worker 0 wakes to consider running the idle hooks.
const IDLE_TICK_MS: u64 = 50;

/// Minimum spacing between idle-hook runs, shared across every pool in the
/// process — hooks do cheap scans, but not 20 of them a second.
const IDLE_HOOK_PERIOD_MS: u64 = 200;

/// Process-wide idle hooks, run (in registration order) by an idle worker.
static IDLE_HOOKS: Mutex<Vec<Box<dyn Fn() + Send + Sync>>> = Mutex::new(Vec::new());

/// Milliseconds-since-first-check timestamp of the last idle-hook run.
static LAST_IDLE_RUN: AtomicU64 = AtomicU64::new(0);

/// Register a housekeeping hook to run on idle worker time (e.g. the plan
/// cache's proactive TTL refresh). Hooks must be cheap when there is
/// nothing to do — they run every [`IDLE_HOOK_PERIOD_MS`] while any pool
/// sits idle — and must never block on pool work (they run *on* a worker).
pub fn add_idle_hook<F: Fn() + Send + Sync + 'static>(f: F) {
    IDLE_HOOKS.lock().unwrap().push(Box::new(f));
}

fn maybe_run_idle_hooks() {
    static START: OnceLock<std::time::Instant> = OnceLock::new();
    let now = START.get_or_init(std::time::Instant::now).elapsed().as_millis() as u64;
    let last = LAST_IDLE_RUN.load(Ordering::Relaxed);
    if now.saturating_sub(last) < IDLE_HOOK_PERIOD_MS {
        return;
    }
    // One winner per period across all idle workers/pools.
    if LAST_IDLE_RUN.compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed).is_err() {
        return;
    }
    let hooks = IDLE_HOOKS.lock().unwrap();
    for h in hooks.iter() {
        h();
    }
}

struct Queue {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    /// Signalled when a task is pushed or shutdown is flagged.
    available: Condvar,
}

/// Completion latch for one [`BatchScope`]: counts outstanding tasks and
/// remembers whether any of them panicked.
struct Latch {
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new() -> Latch {
        Latch { pending: Mutex::new(0), done: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn add(&self) {
        *self.pending.lock().unwrap() += 1;
    }

    fn count_down(&self) {
        let mut p = self.pending.lock().unwrap();
        *p -= 1;
        if *p == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.pending.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut p = self.pending.lock().unwrap();
        while *p > 0 {
            p = self.done.wait(p).unwrap();
        }
    }
}

/// A pool of persistent worker threads executing queued coding tasks.
pub struct WorkPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkPool {
    /// Spawn `workers` (≥ 1) long-lived worker threads.
    pub fn new(workers: usize) -> WorkPool {
        WorkPool::with_pinning(workers, false)
    }

    /// [`WorkPool::new`] with optional CPU affinity: when `pin` is set,
    /// each worker pins itself to a distinct CPU following the
    /// package-major plan from [`super::topo::plan_pinning`] (best-effort —
    /// a rejected mask leaves the worker floating).
    pub fn with_pinning(workers: usize, pin: bool) -> WorkPool {
        let workers = workers.max(1);
        let plan: Vec<Option<usize>> =
            if pin { super::topo::plan_pinning(workers) } else { vec![None; workers] };
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { tasks: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let cpu = plan.get(i).copied().flatten();
                std::thread::Builder::new()
                    .name(format!("gf-worker-{i}"))
                    .spawn(move || worker_loop(&shared, cpu, i == 0))
                    .expect("spawn gf worker")
            })
            .collect();
        WorkPool { shared, workers: handles }
    }

    /// Number of worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    fn push(&self, task: Task) {
        let mut q = self.shared.queue.lock().unwrap();
        q.tasks.push_back(task);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Pop one queued task without blocking (caller-runs helping).
    fn try_pop(&self) -> Option<Task> {
        self.shared.queue.lock().unwrap().tasks.pop_front()
    }

    /// Open a batch scope: `f` may submit any number of tasks borrowing
    /// data that outlives the `scope` call; all of them have completed by
    /// the time `scope` returns. The calling thread helps execute queued
    /// tasks while it waits, so nested scopes (a pooled task opening its
    /// own scope) make progress instead of deadlocking.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope BatchScope<'scope, 'env>) -> R,
    {
        let latch = Arc::new(Latch::new());
        let scope = BatchScope {
            pool: self,
            latch: Arc::clone(&latch),
            _env: PhantomData,
            _scope: PhantomData,
        };
        // Even if `f` unwinds we must wait for already-submitted tasks —
        // they borrow `'env` data that is freed once we return.
        let result = panic::catch_unwind(AssertUnwindSafe(|| f(&scope)));
        while !latch.is_done() {
            match self.try_pop() {
                Some(task) => task(),
                None => {
                    latch.wait();
                    break;
                }
            }
        }
        match result {
            Err(payload) => panic::resume_unwind(payload),
            Ok(r) => {
                if latch.panicked.load(Ordering::Acquire) {
                    panic!("GF worker task panicked");
                }
                r
            }
        }
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkPool").field("workers", &self.workers.len()).finish()
    }
}

fn worker_loop(shared: &Shared, pin_to: Option<usize>, idler: bool) {
    if let Some(cpu) = pin_to {
        let _ = super::topo::pin_current_thread(cpu);
    }
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break Some(t);
                }
                if q.shutdown {
                    break None;
                }
                if idler {
                    // Worker 0 doubles as the housekeeping thread: wake on
                    // a short tick and offer idle time to the hooks.
                    let (guard, timeout) = shared
                        .available
                        .wait_timeout(q, Duration::from_millis(IDLE_TICK_MS))
                        .unwrap();
                    q = guard;
                    if timeout.timed_out() && q.tasks.is_empty() && !q.shutdown {
                        drop(q);
                        maybe_run_idle_hooks();
                        q = shared.queue.lock().unwrap();
                    }
                } else {
                    q = shared.available.wait(q).unwrap();
                }
            }
        };
        match task {
            Some(t) => t(),
            None => return,
        }
    }
}

/// Handle for submitting tasks into one batch; created by
/// [`WorkPool::scope`]. `'env` is the lifetime of the data tasks may
/// borrow — everything alive across the whole `scope` call.
pub struct BatchScope<'scope, 'env: 'scope> {
    pool: &'scope WorkPool,
    latch: Arc<Latch>,
    _env: PhantomData<&'env mut &'env ()>,
    _scope: PhantomData<&'scope mut &'scope ()>,
}

impl<'scope, 'env> BatchScope<'scope, 'env> {
    /// Enqueue `f` onto the pool. It runs on some worker (or on the
    /// scoping thread while it drains the queue) before the enclosing
    /// [`WorkPool::scope`] returns.
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.latch.add();
        let latch = Arc::clone(&self.latch);
        let wrapped = move || {
            if panic::catch_unwind(AssertUnwindSafe(f)).is_err() {
                latch.panicked.store(true, Ordering::Release);
            }
            latch.count_down();
        };
        let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(wrapped);
        // SAFETY: lifetime erasure to store the task in the 'static queue.
        // `WorkPool::scope` does not return until the latch reports every
        // submitted task completed (even when the scope body unwinds), so
        // all `'env` borrows captured by the task are live for its entire
        // execution — the same guarantee `std::thread::scope` provides.
        let boxed: Task = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(boxed)
        };
        self.pool.push(boxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn empty_scope_returns() {
        let pool = WorkPool::new(2);
        let r = pool.scope(|_| 41 + 1);
        assert_eq!(r, 42);
    }

    #[test]
    fn tasks_see_and_mutate_borrowed_data() {
        let pool = WorkPool::new(4);
        let mut data = vec![0u32; 1024];
        pool.scope(|s| {
            for (i, chunk) in data.chunks_mut(100).enumerate() {
                s.submit(move || {
                    for v in chunk.iter_mut() {
                        *v = i as u32;
                    }
                });
            }
        });
        for (i, chunk) in data.chunks(100).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32), "chunk {i}");
        }
    }

    #[test]
    fn many_scopes_reuse_the_same_workers() {
        let pool = WorkPool::new(2);
        let hits = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.scope(|s| {
                for _ in 0..8 {
                    s.submit(|| {
                        hits.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 800);
    }

    #[test]
    fn nested_scopes_progress() {
        let pool = WorkPool::new(1); // single worker: nesting must caller-run
        let total = AtomicUsize::new(0);
        pool.scope(|s| {
            let (pool_ref, total_ref) = (&pool, &total);
            for _ in 0..4 {
                s.submit(move || {
                    pool_ref.scope(|inner| {
                        for _ in 0..4 {
                            inner.submit(move || {
                                total_ref.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn task_panic_propagates_after_batch_settles() {
        let pool = WorkPool::new(2);
        let ran = AtomicUsize::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.submit(|| panic!("boom"));
                for _ in 0..4 {
                    s.submit(|| {
                        ran.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }));
        assert!(result.is_err(), "worker panic must surface on the scoping thread");
        assert_eq!(ran.load(Ordering::Relaxed), 4, "other tasks still completed");
        // pool stays usable after a panicked batch
        assert_eq!(pool.scope(|_| 7), 7);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkPool::new(3);
        pool.scope(|s| {
            for _ in 0..16 {
                s.submit(|| std::thread::yield_now());
            }
        });
        drop(pool); // must not hang
    }

    #[test]
    fn pinned_pool_executes_tasks() {
        // Pinning is best-effort; whatever the affinity calls did, the pool
        // must still run every task and join cleanly.
        let pool = WorkPool::with_pinning(4, true);
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..32 {
                s.submit(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn idle_hook_runs_on_worker_idle_time() {
        static FIRED: AtomicUsize = AtomicUsize::new(0);
        add_idle_hook(|| {
            FIRED.fetch_add(1, Ordering::Relaxed);
        });
        let _pool = WorkPool::new(1); // idle from birth
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while FIRED.load(Ordering::Relaxed) == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(FIRED.load(Ordering::Relaxed) > 0, "idle hook never ran");
    }
}
