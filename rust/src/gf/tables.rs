//! Scalar GF(2^8) arithmetic over the polynomial 0x11D.
//!
//! Exp/log tables are built at compile time (`const fn`), so field ops are
//! branch-light table lookups with zero startup cost.

/// Field polynomial: x^8 + x^4 + x^3 + x^2 + 1.
pub const POLY: u16 = 0x11D;

/// Multiplicative generator of GF(2^8) under 0x11D.
pub const GENERATOR: u8 = 2;

const fn build_exp() -> [u8; 512] {
    // exp[i] = GENERATOR^i; doubled length so mul can skip the mod-255.
    let mut exp = [0u8; 512];
    let mut x: u16 = 1;
    let mut i = 0;
    while i < 255 {
        exp[i] = x as u8;
        exp[i + 255] = x as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= POLY;
        }
        i += 1;
    }
    // exp[510..512] never read (max index is 254+254=508).
    exp[510] = exp[0];
    exp[511] = exp[1];
    exp
}

const fn build_log(exp: &[u8; 512]) -> [u8; 256] {
    let mut log = [0u8; 256];
    let mut i = 0;
    while i < 255 {
        log[exp[i] as usize] = i as u8;
        i += 1;
    }
    log // log[0] is 0 by convention and must never be used.
}

/// `EXP[i] = g^i` for `i in 0..510` (doubled to avoid a mod in `gf_mul`).
pub const EXP: [u8; 512] = build_exp();
/// `LOG[x] = log_g(x)` for nonzero `x`.
pub const LOG: [u8; 256] = build_log(&EXP);

/// Field multiplication.
#[inline]
pub fn gf_mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        0
    } else {
        EXP[LOG[a as usize] as usize + LOG[b as usize] as usize]
    }
}

/// Field exponentiation `g^i` of the generator.
#[inline]
pub fn gf_exp(i: usize) -> u8 {
    EXP[i % 255]
}

/// Discrete log (panics on 0).
#[inline]
pub fn gf_log(x: u8) -> u8 {
    assert!(x != 0, "log of zero");
    LOG[x as usize]
}

/// Multiplicative inverse (panics on 0).
#[inline]
pub fn gf_inv(a: u8) -> u8 {
    assert!(a != 0, "inverse of zero");
    EXP[255 - LOG[a as usize] as usize]
}

/// Field division `a / b` (panics if `b == 0`).
#[inline]
pub fn gf_div(a: u8, b: u8) -> u8 {
    assert!(b != 0, "division by zero");
    if a == 0 {
        0
    } else {
        EXP[LOG[a as usize] as usize + 255 - LOG[b as usize] as usize]
    }
}

/// `a^e` for arbitrary base `a` and exponent `e`.
#[inline]
pub fn gf_pow(a: u8, e: usize) -> u8 {
    if e == 0 {
        return 1;
    }
    if a == 0 {
        return 0;
    }
    EXP[(LOG[a as usize] as usize * e) % 255]
}

/// Carry-less "schoolbook" multiply used only to cross-check the tables.
pub fn gf_mul_slow(a: u8, b: u8) -> u8 {
    let mut acc: u16 = 0;
    let mut a16 = a as u16;
    let mut b16 = b as u16;
    while b16 != 0 {
        if b16 & 1 != 0 {
            acc ^= a16;
        }
        b16 >>= 1;
        a16 <<= 1;
        if a16 & 0x100 != 0 {
            a16 ^= POLY;
        }
    }
    acc as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_log_roundtrip() {
        for x in 1..=255u8 {
            assert_eq!(gf_exp(gf_log(x) as usize), x);
        }
    }

    #[test]
    fn exp_is_255_periodic_and_surjective() {
        let mut seen = [false; 256];
        for i in 0..255 {
            seen[EXP[i] as usize] = true;
            assert_eq!(EXP[i], EXP[i + 255]);
        }
        assert!(!seen[0]);
        assert!(seen[1..].iter().all(|&s| s));
    }

    #[test]
    fn mul_matches_slow_mul_exhaustive() {
        for a in 0..=255u8 {
            for b in 0..=255u8 {
                assert_eq!(gf_mul(a, b), gf_mul_slow(a, b), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn mul_identity_and_zero() {
        for a in 0..=255u8 {
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(1, a), a);
            assert_eq!(gf_mul(a, 0), 0);
            assert_eq!(gf_mul(0, a), 0);
        }
    }

    #[test]
    fn mul_commutative_associative_sample() {
        // associativity on a full sweep is 16M triples; sample a lattice.
        for a in (0..=255u8).step_by(7) {
            for b in (0..=255u8).step_by(11) {
                assert_eq!(gf_mul(a, b), gf_mul(b, a));
                for c in (0..=255u8).step_by(13) {
                    assert_eq!(gf_mul(gf_mul(a, b), c), gf_mul(a, gf_mul(b, c)));
                }
            }
        }
    }

    #[test]
    fn distributivity_over_xor_sample() {
        for a in (0..=255u8).step_by(5) {
            for b in (0..=255u8).step_by(9) {
                for c in (0..=255u8).step_by(17) {
                    assert_eq!(gf_mul(a, b ^ c), gf_mul(a, b) ^ gf_mul(a, c));
                }
            }
        }
    }

    #[test]
    fn inverse_law() {
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, gf_inv(a)), 1);
            assert_eq!(gf_div(a, a), 1);
        }
    }

    #[test]
    fn div_is_mul_by_inverse() {
        for a in (0..=255u8).step_by(3) {
            for b in (1..=255u8).step_by(5) {
                assert_eq!(gf_div(a, b), gf_mul(a, gf_inv(b)));
            }
        }
    }

    #[test]
    fn pow_laws() {
        for a in 1..=255u8 {
            assert_eq!(gf_pow(a, 0), 1);
            assert_eq!(gf_pow(a, 1), a);
            assert_eq!(gf_pow(a, 2), gf_mul(a, a));
            assert_eq!(gf_pow(a, 255), 1); // Lagrange: |GF(256)^*| = 255
            assert_eq!(gf_pow(a, 256), a);
        }
        assert_eq!(gf_pow(0, 0), 1);
        assert_eq!(gf_pow(0, 5), 0);
    }

    #[test]
    #[should_panic]
    fn inv_zero_panics() {
        gf_inv(0);
    }

    #[test]
    #[should_panic]
    fn div_zero_panics() {
        gf_div(3, 0);
    }
}
