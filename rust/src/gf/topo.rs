//! CPU / cache / package topology detection for the memory-system layer.
//!
//! Everything here is best-effort: values come from `/sys` on Linux and
//! fall back to safe defaults (64-byte lines, a 32 MiB LLC, one package
//! holding every CPU) on other platforms, inside containers that mask
//! `/sys`, or on exotic kernels. Callers must treat the answers as hints —
//! they size the non-temporal-store threshold and the worker-pinning plan,
//! both of which are correct (just less tuned) under the fallback.

use std::sync::OnceLock;

/// Cacheline size in bytes (the alignment unit for pooled buffers and
/// streaming stores). Falls back to 64, which is right on every x86_64
/// and aarch64 part this crate targets.
pub fn cacheline_bytes() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| {
        read_trimmed("/sys/devices/system/cpu/cpu0/cache/index0/coherency_line_size")
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n.is_power_of_two() && (16..=1024).contains(&n))
            .unwrap_or(64)
    })
}

/// Last-level cache size in bytes: the largest cache reported under
/// `cpu0/cache/index*`. Streaming stores only pay off once an output span
/// no longer fits here. Fallback: 32 MiB (a typical server LLC — err large
/// so the auto threshold never streams cache-resident outputs).
pub fn llc_bytes() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| detect_llc().unwrap_or(32 << 20))
}

fn detect_llc() -> Option<usize> {
    let mut best = None;
    for idx in 0..8 {
        let base = format!("/sys/devices/system/cpu/cpu0/cache/index{idx}");
        let Some(size) = read_trimmed(&format!("{base}/size")) else {
            continue;
        };
        let Some(bytes) = parse_size(&size) else {
            continue;
        };
        if best.is_none_or(|b| bytes > b) {
            best = Some(bytes);
        }
    }
    best
}

/// Parse a `/sys` cache-size string (`"32768K"`, `"1M"`, plain bytes).
pub(crate) fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (num, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1usize << 10),
        b'M' | b'm' => (&s[..s.len() - 1], 1usize << 20),
        b'G' | b'g' => (&s[..s.len() - 1], 1usize << 30),
        _ => (s, 1),
    };
    num.trim().parse::<usize>().ok().map(|n| n.checked_mul(mult).unwrap_or(usize::MAX))
}

/// Parse a `/sys` CPU-list string (`"0-3,8,10-11"`) into CPU ids.
pub(crate) fn parse_cpu_list(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b && b - a < 4096 {
                    out.extend(a..=b);
                }
            }
        } else if let Ok(n) = part.parse::<usize>() {
            out.push(n);
        }
    }
    out
}

/// Online CPUs grouped by physical package (socket), packages sorted by id
/// and CPUs sorted within each. Fallback: one package holding
/// `0..available_parallelism()`.
pub fn packages() -> &'static [Vec<usize>] {
    static V: OnceLock<Vec<Vec<usize>>> = OnceLock::new();
    V.get_or_init(|| {
        detect_packages().unwrap_or_else(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            vec![(0..n).collect()]
        })
    })
}

fn detect_packages() -> Option<Vec<Vec<usize>>> {
    let online = read_trimmed("/sys/devices/system/cpu/online")?;
    let cpus = parse_cpu_list(&online);
    if cpus.is_empty() {
        return None;
    }
    let mut by_pkg: Vec<(usize, Vec<usize>)> = Vec::new();
    for &cpu in &cpus {
        let pkg = read_trimmed(&format!(
            "/sys/devices/system/cpu/cpu{cpu}/topology/physical_package_id"
        ))
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(0);
        match by_pkg.iter_mut().find(|(id, _)| *id == pkg) {
            Some((_, v)) => v.push(cpu),
            None => by_pkg.push((pkg, vec![cpu])),
        }
    }
    by_pkg.sort_by_key(|(id, _)| *id);
    let mut pkgs: Vec<Vec<usize>> = by_pkg.into_iter().map(|(_, v)| v).collect();
    for p in &mut pkgs {
        p.sort_unstable();
    }
    Some(pkgs)
}

/// Assign `workers` worker indices to CPUs, filling one package before
/// spilling into the next so a stripe's lanes (which the engine hands to
/// consecutive workers) share a socket/LLC domain. More workers than CPUs
/// wrap around. An empty topology yields no pins.
pub fn plan_pinning(workers: usize) -> Vec<Option<usize>> {
    let pkgs = packages();
    let flat: Vec<usize> = pkgs.iter().flat_map(|p| p.iter().copied()).collect();
    if flat.is_empty() {
        return vec![None; workers];
    }
    (0..workers).map(|i| Some(flat[i % flat.len()])).collect()
}

/// Pin the calling thread to a single CPU. Returns `false` (and leaves the
/// affinity mask alone) when the platform has no affinity syscall or the
/// kernel rejects the mask (cgroup cpuset exclusions, offline CPU).
pub fn pin_current_thread(cpu: usize) -> bool {
    pin_impl(cpu)
}

#[cfg(target_os = "linux")]
fn pin_impl(cpu: usize) -> bool {
    // Raw syscall binding: the crate is dependency-free, so declare the
    // glibc affinity entry point directly instead of pulling in `libc`.
    // cpu_set_t is a 1024-bit mask (128 bytes) on Linux.
    #[repr(C)]
    struct CpuSet {
        bits: [u64; 16],
    }
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    if cpu >= 1024 {
        return false;
    }
    let mut set = CpuSet { bits: [0; 16] };
    set.bits[cpu / 64] = 1u64 << (cpu % 64);
    // pid 0 = calling thread.
    unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_impl(_cpu: usize) -> bool {
    false
}

fn read_trimmed(path: &str) -> Option<String> {
    std::fs::read_to_string(path).ok().map(|s| s.trim().to_string())
}

/// One-line human summary for `unilrc engine`.
pub fn describe() -> String {
    let pkgs = packages();
    let ncpus: usize = pkgs.iter().map(|p| p.len()).sum();
    format!(
        "cacheline {} B, LLC {:.1} MiB, {} package(s) / {} cpu(s)",
        cacheline_bytes(),
        llc_bytes() as f64 / (1 << 20) as f64,
        pkgs.len(),
        ncpus
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_parsing() {
        assert_eq!(parse_size("32768K"), Some(32768 << 10));
        assert_eq!(parse_size("1M"), Some(1 << 20));
        assert_eq!(parse_size(" 2G "), Some(2 << 30));
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("abc"), None);
    }

    #[test]
    fn cpu_list_parsing() {
        assert_eq!(parse_cpu_list("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpu_list("0-1,8,10-11"), vec![0, 1, 8, 10, 11]);
        assert_eq!(parse_cpu_list(""), Vec::<usize>::new());
        assert_eq!(parse_cpu_list("7"), vec![7]);
    }

    #[test]
    fn fallbacks_are_sane() {
        assert!(cacheline_bytes().is_power_of_two());
        assert!(llc_bytes() >= 1 << 20);
        let pkgs = packages();
        assert!(!pkgs.is_empty());
        assert!(pkgs.iter().map(|p| p.len()).sum::<usize>() >= 1);
    }

    #[test]
    fn pinning_plan_covers_workers() {
        let plan = plan_pinning(8);
        assert_eq!(plan.len(), 8);
        // with any non-empty topology every slot gets a CPU
        assert!(plan.iter().all(|p| p.is_some()));
    }

    #[test]
    fn pin_current_thread_smoke() {
        // Pin to CPU 0 (always online when /sys exists); on non-Linux this
        // is a no-op returning false — either way it must not panic.
        let _ = pin_current_thread(0);
    }
}
