//! Runtime-dispatched, parallel-striped GF(2^8) engine.
//!
//! [`Kernel`] is the instruction-set tier (detected once at startup,
//! overridable via `UNILRC_GF_KERNEL` / `--gf-kernel`); [`GfEngine`] bundles
//! a kernel with a striped parallel executor that splits large blocks into
//! cache-sized lanes and fans them across a scoped thread pool. All tiers
//! and both execution modes produce byte-identical results — GF(2^8) is
//! exact and XOR-accumulation is order-independent (`tests/gf_simd.rs`
//! asserts this differentially).
//!
//! The process-wide engine ([`engine`]) backs the hot-path entry points in
//! [`super::slice`], so every encode / repair / decode in the repo runs at
//! the selected tier without call sites knowing about dispatch.

use super::slice::{self, NibbleTables};
use std::sync::OnceLock;

/// Instruction-set tier of the multiply-accumulate kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable SWAR bit-plane loop (`u64` registers) — always available.
    Scalar,
    /// x86_64 `PSHUFB` split-nibble lookups, 16 bytes/op.
    Ssse3,
    /// x86_64 `VPSHUFB`, 32 bytes/op.
    Avx2,
    /// AArch64 `TBL` (`vqtbl1q_u8`), 16 bytes/op.
    Neon,
}

impl Kernel {
    /// Every tier, fastest first.
    pub fn all() -> [Kernel; 4] {
        [Kernel::Avx2, Kernel::Neon, Kernel::Ssse3, Kernel::Scalar]
    }

    /// Best tier the running CPU supports.
    pub fn detect() -> Kernel {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return Kernel::Avx2;
            }
            if is_x86_feature_detected!("ssse3") {
                return Kernel::Ssse3;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    }

    /// Can this tier run on the current CPU?
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => is_x86_feature_detected!("ssse3"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Ssse3 => "ssse3",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Parse a tier name (`auto` resolves to [`Kernel::detect`]).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "swar" => Some(Kernel::Scalar),
            "ssse3" => Some(Kernel::Ssse3),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            "auto" => Some(Kernel::detect()),
            _ => None,
        }
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Default lane size for the striped executor: half an L2-ish working set,
/// so one lane's src+dst stay cache-resident while it is processed.
const DEFAULT_LANE: usize = 64 * 1024;

/// Minimum total bytes of input a call must touch before worker threads are
/// engaged — below this the scoped-spawn overhead (~tens of µs) dominates.
const DEFAULT_PAR_WORK: usize = 2 << 20;

/// A GF(2^8) execution engine: one kernel tier + striping parameters.
#[derive(Debug, Clone)]
pub struct GfEngine {
    kernel: Kernel,
    threads: usize,
    lane: usize,
    par_work: usize,
}

impl Default for GfEngine {
    fn default() -> Self {
        GfEngine::auto()
    }
}

impl GfEngine {
    /// Detected kernel, one worker per available core.
    pub fn auto() -> GfEngine {
        GfEngine::new(Kernel::detect())
            .with_threads(std::thread::available_parallelism().map_or(1, |n| n.get()))
    }

    /// Single-threaded portable baseline (the seed behaviour).
    pub fn scalar() -> GfEngine {
        GfEngine::new(Kernel::Scalar)
    }

    /// Engine on a specific tier; silently falls back to [`Kernel::Scalar`]
    /// if the tier is not available on this CPU, so a config written on one
    /// machine stays runnable on another.
    pub fn new(kernel: Kernel) -> GfEngine {
        let kernel = if kernel.available() { kernel } else { Kernel::Scalar };
        GfEngine { kernel, threads: 1, lane: DEFAULT_LANE, par_work: DEFAULT_PAR_WORK }
    }

    /// Engine configured from the environment:
    /// `UNILRC_GF_KERNEL` (scalar|ssse3|avx2|neon|auto), `UNILRC_GF_THREADS`,
    /// `UNILRC_GF_LANE_KB`.
    pub fn from_env() -> GfEngine {
        let mut e = GfEngine::auto();
        if let Ok(k) = std::env::var("UNILRC_GF_KERNEL") {
            if let Some(k) = Kernel::parse(&k) {
                e = e.with_kernel(k);
            }
        }
        if let Ok(t) = std::env::var("UNILRC_GF_THREADS") {
            if let Ok(t) = t.parse::<usize>() {
                e = e.with_threads(t);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_LANE_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_lane(kb * 1024);
            }
        }
        e
    }

    pub fn with_kernel(mut self, kernel: Kernel) -> GfEngine {
        self.kernel = if kernel.available() { kernel } else { Kernel::Scalar };
        self
    }

    pub fn with_threads(mut self, threads: usize) -> GfEngine {
        self.threads = threads.max(1);
        self
    }

    pub fn with_lane(mut self, lane_bytes: usize) -> GfEngine {
        self.lane = lane_bytes.max(64);
        self
    }

    /// Lower the parallelism threshold (tests use this to exercise the
    /// striped path on small blocks).
    pub fn with_par_work(mut self, bytes: usize) -> GfEngine {
        self.par_work = bytes;
        self
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// One-line description for logs and `unilrc engine`.
    pub fn describe(&self) -> String {
        format!(
            "kernel={} threads={} lane={}KiB",
            self.kernel,
            self.threads,
            self.lane / 1024
        )
    }

    // ------------------------------------------------------------ slice ops

    /// `dst ^= c · src` on the selected tier.
    pub fn mul_acc(&self, c: u8, src: &[u8], dst: &mut [u8]) {
        assert_eq!(dst.len(), src.len(), "mul_acc length mismatch");
        // The SWAR tier derives its plane constants from `c` directly —
        // don't build lookup tables it would never read.
        if self.kernel == Kernel::Scalar {
            return slice::mul_acc_slice_scalar(c, src, dst);
        }
        match c {
            0 => {}
            1 => self.xor(dst, src),
            _ => self.mul_acc_kernel(&NibbleTables::new(c), src, dst),
        }
    }

    /// `dst ^= c · src` with the coefficient's tables precomputed (the
    /// cached-plan hot path: no per-call table build).
    pub fn mul_acc_t(&self, t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        assert_eq!(dst.len(), src.len(), "mul_acc_t length mismatch");
        match t.c {
            0 => {}
            1 => self.xor(dst, src),
            _ => self.mul_acc_kernel(t, src, dst),
        }
    }

    fn mul_acc_kernel(&self, t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        // SAFETY: `GfEngine::new`/`with_kernel` only store tiers that
        // `Kernel::available()` confirmed on this CPU.
        match self.kernel {
            Kernel::Scalar => slice::mul_acc_slice_scalar(t.c, src, dst),
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => unsafe { super::simd::x86_64::mul_acc_ssse3(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::mul_acc_avx2(t, src, dst) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::mul_acc_neon(t, src, dst) },
            _ => slice::mul_acc_slice_scalar(t.c, src, dst),
        }
    }

    /// `dst ^= src` on the selected tier.
    pub fn xor(&self, dst: &mut [u8], src: &[u8]) {
        assert_eq!(dst.len(), src.len(), "xor length mismatch");
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::xor_avx2(dst, src) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::xor_neon(dst, src) },
            _ => slice::xor_slice_scalar(dst, src),
        }
    }

    // -------------------------------------------------------- striped ops

    /// Worker count for a call touching `block`-byte rows and `work` total
    /// input bytes; 1 means run inline.
    fn workers_for(&self, block: usize, work: usize) -> usize {
        if self.threads <= 1 || work < self.par_work || block < 2 * self.lane {
            1
        } else {
            self.threads.min(block.div_ceil(self.lane))
        }
    }

    /// `dst = srcs[0] ^ srcs[1] ^ …`, striped across workers for large
    /// blocks (the UniLRC repair path).
    pub fn fold_blocks(&self, dst: &mut [u8], srcs: &[&[u8]]) {
        assert!(!srcs.is_empty(), "fold needs at least one source");
        for s in srcs {
            assert_eq!(s.len(), dst.len(), "fold length mismatch");
        }
        let block = dst.len();
        let workers = self.workers_for(block, block * srcs.len());
        if workers <= 1 {
            dst.copy_from_slice(srcs[0]);
            for s in &srcs[1..] {
                self.xor(dst, s);
            }
            return;
        }
        let lane = self.lane;
        let mut lanes: Vec<(usize, &mut [u8])> = Vec::with_capacity(block.div_ceil(lane));
        for (l, chunk) in dst.chunks_mut(lane).enumerate() {
            lanes.push((l * lane, chunk));
        }
        let per = lanes.len().div_ceil(workers);
        std::thread::scope(|scope| {
            while !lanes.is_empty() {
                let group: Vec<_> = lanes.drain(..per.min(lanes.len())).collect();
                scope.spawn(move || {
                    for (off, chunk) in group {
                        let w = chunk.len();
                        chunk.copy_from_slice(&srcs[0][off..off + w]);
                        for s in &srcs[1..] {
                            self.xor(chunk, &s[off..off + w]);
                        }
                    }
                });
            }
        });
    }

    /// Matrix-style coding primitive: `outs[i] = ⊕_j coeff[i][j] · srcs[j]`,
    /// striped across workers. Each worker owns a disjoint byte range of
    /// every output row and walks it source-major, so one cache-resident
    /// lane of each source is scattered into all rows before moving on.
    pub fn matmul_blocks(&self, coeff: &[&[u8]], srcs: &[&[u8]], outs: &mut [Vec<u8>]) {
        let tables: Vec<Vec<NibbleTables>> = coeff
            .iter()
            .map(|row| row.iter().map(|&c| NibbleTables::new(c)).collect())
            .collect();
        self.matmul_blocks_t(&tables, srcs, outs);
    }

    /// [`Self::matmul_blocks`] with per-coefficient tables prebuilt — the
    /// entry point for cached decode plans.
    pub fn matmul_blocks_t(&self, tables: &[Vec<NibbleTables>], srcs: &[&[u8]], outs: &mut [Vec<u8>]) {
        assert_eq!(tables.len(), outs.len(), "row count mismatch");
        let block = srcs.first().map_or(0, |s| s.len());
        for (row, out) in tables.iter().zip(outs.iter_mut()) {
            assert_eq!(row.len(), srcs.len(), "column count mismatch");
            assert_eq!(out.len(), block, "output block size mismatch");
        }
        let workers = self.workers_for(block, block * srcs.len() * outs.len().max(1));
        if workers <= 1 || outs.is_empty() {
            let mut full: Vec<&mut [u8]> = outs.iter_mut().map(|o| o.as_mut_slice()).collect();
            self.matmul_lane(tables, srcs, 0, &mut full);
            return;
        }
        let lane = self.lane;
        let nlanes = block.div_ceil(lane);
        // Transpose row-major chunking into lane-major work items: lane l
        // holds the l-th chunk of every output row (disjoint &mut borrows).
        let mut row_chunks: Vec<_> = outs.iter_mut().map(|o| o.chunks_mut(lane)).collect();
        let mut lanes: Vec<(usize, Vec<&mut [u8]>)> = Vec::with_capacity(nlanes);
        for l in 0..nlanes {
            let chunk: Vec<&mut [u8]> =
                row_chunks.iter_mut().map(|it| it.next().expect("lane chunk")).collect();
            lanes.push((l * lane, chunk));
        }
        let per = nlanes.div_ceil(workers);
        std::thread::scope(|scope| {
            while !lanes.is_empty() {
                let mut group: Vec<_> = lanes.drain(..per.min(lanes.len())).collect();
                scope.spawn(move || {
                    for (off, louts) in group.iter_mut() {
                        self.matmul_lane(tables, srcs, *off, louts);
                    }
                });
            }
        });
    }

    /// One lane of the matmul: outputs are the `[off..off+w)` sub-slices of
    /// the full rows; sources are indexed with the same offset.
    fn matmul_lane(&self, tables: &[Vec<NibbleTables>], srcs: &[&[u8]], off: usize, louts: &mut [&mut [u8]]) {
        for out in louts.iter_mut() {
            out.fill(0);
        }
        for (j, src) in srcs.iter().enumerate() {
            for (row, out) in tables.iter().zip(louts.iter_mut()) {
                let w = out.len();
                self.mul_acc_t(&row[j], &src[off..off + w], out);
            }
        }
    }
}

static GLOBAL: OnceLock<GfEngine> = OnceLock::new();

/// The process-wide engine. First use freezes it: initialized from the
/// environment ([`GfEngine::from_env`]) unless [`install`] ran earlier.
pub fn engine() -> &'static GfEngine {
    GLOBAL.get_or_init(GfEngine::from_env)
}

/// Install a specific engine as the process-wide one (CLI `--gf-kernel` /
/// config `[experiment] gf_kernel`). Returns `false` if the engine was
/// already initialized — the caller should warn that the override is late.
pub fn install(e: GfEngine) -> bool {
    GLOBAL.set(e).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::gf_mul;
    use crate::prng::Prng;

    fn available_kernels() -> Vec<Kernel> {
        Kernel::all().into_iter().filter(|k| k.available()).collect()
    }

    #[test]
    fn detect_is_available() {
        assert!(Kernel::detect().available());
    }

    #[test]
    fn parse_roundtrip() {
        for k in Kernel::all() {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert!(Kernel::parse("auto").is_some());
        assert_eq!(Kernel::parse("nope"), None);
    }

    #[test]
    fn unavailable_kernel_falls_back_to_scalar() {
        // At most one of AVX2/NEON exists on any one machine, so whichever
        // is foreign must clamp to scalar rather than crash later.
        for k in Kernel::all() {
            let e = GfEngine::new(k);
            assert!(e.kernel().available());
        }
    }

    #[test]
    fn every_tier_matches_reference_mul_acc() {
        let mut p = Prng::new(17);
        let src = p.bytes(1000);
        let init = p.bytes(1000);
        for k in available_kernels() {
            let e = GfEngine::new(k);
            for c in [0u8, 1, 2, 0x53, 0xFF] {
                let mut dst = init.clone();
                e.mul_acc(c, &src, &mut dst);
                let expect: Vec<u8> =
                    init.iter().zip(&src).map(|(&d, &s)| d ^ gf_mul(c, s)).collect();
                assert_eq!(dst, expect, "kernel={k} c={c}");
            }
        }
    }

    #[test]
    fn striped_matmul_matches_serial() {
        let mut p = Prng::new(18);
        let block = 10_000; // not a lane multiple: exercises the short tail lane
        let srcs: Vec<Vec<u8>> = (0..5).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let rows: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(5)).collect();
        let rrefs: Vec<&[u8]> = rows.iter().map(|v| v.as_slice()).collect();

        let serial = GfEngine::scalar();
        let mut expect = vec![vec![0u8; block]; 3];
        serial.matmul_blocks(&rrefs, &refs, &mut expect);

        for k in available_kernels() {
            let par = GfEngine::new(k).with_threads(4).with_lane(1024).with_par_work(0);
            let mut got = vec![vec![1u8; block]; 3]; // nonzero: checks overwrite
            par.matmul_blocks(&rrefs, &refs, &mut got);
            assert_eq!(got, expect, "kernel={k}");
        }
    }

    #[test]
    fn striped_fold_matches_serial() {
        let mut p = Prng::new(19);
        let block = 7777;
        let srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut expect = vec![0u8; block];
        GfEngine::scalar().fold_blocks(&mut expect, &refs);
        for k in available_kernels() {
            let par = GfEngine::new(k).with_threads(3).with_lane(512).with_par_work(0);
            let mut got = vec![9u8; block];
            par.fold_blocks(&mut got, &refs);
            assert_eq!(got, expect, "kernel={k}");
        }
    }

    #[test]
    fn empty_matmul_ok() {
        let mut outs: Vec<Vec<u8>> = vec![];
        GfEngine::auto().matmul_blocks(&[], &[], &mut outs);
        assert!(outs.is_empty());
    }
}
