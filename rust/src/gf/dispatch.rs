//! Runtime-dispatched, parallel-striped GF(2^8) engine.
//!
//! [`Kernel`] is the instruction-set tier (detected once at startup,
//! overridable via `UNILRC_GF_KERNEL` / `--gf-kernel`); [`GfEngine`] bundles
//! a kernel with a striped parallel executor that splits large blocks into
//! cache-sized lanes and fans them across a persistent [`WorkPool`]
//! (`gf/workpool.rs`) — workers are spawned once per engine and reused by
//! every call, so dispatch costs a queue push instead of a thread spawn.
//! All tiers and both execution modes produce byte-identical results —
//! GF(2^8) is exact and XOR-accumulation is order-independent
//! (`tests/gf_simd.rs` asserts this differentially).
//!
//! Beyond the per-call striped entry points ([`GfEngine::matmul_blocks`],
//! [`GfEngine::fold_blocks`]), the engine exposes a *batched* mode:
//! [`GfEngine::batch`] opens a [`CodingBatch`] into which whole multi-stripe
//! events (full-node recovery, degraded-read fan-outs, bulk ingest) enqueue
//! every stripe's combine at once; the pool schedules tasks across stripes,
//! so small blocks that are below the intra-block striping threshold still
//! parallelize across the event (`tests/batch.rs`). Task granularity is
//! *adaptive* ([`GfEngine::batch_chunk`]): derived per batch from total
//! work vs. worker count (~2–4 tasks per worker per wave, floored at the
//! lane size), so a degraded burst of thousands of stripes no longer
//! floods the queue with lane-sized tasks; `--gf-chunk-kb` /
//! `UNILRC_GF_CHUNK_KB` pins it explicitly (`tests/chunking.rs`).
//!
//! The process-wide engine ([`engine`]) backs the hot-path entry points in
//! [`super::slice`], so every encode / repair / decode in the repo runs at
//! the selected tier without call sites knowing about dispatch.

use super::slice::{self, NibbleTables};
use super::workpool::{BatchScope, WorkPool};
use std::sync::{Arc, OnceLock};

/// Instruction-set tier of the multiply-accumulate kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable SWAR bit-plane loop (`u64` registers) — always available.
    Scalar,
    /// x86_64 `PSHUFB` split-nibble lookups, 16 bytes/op.
    Ssse3,
    /// x86_64 `VPSHUFB`, 32 bytes/op.
    Avx2,
    /// x86_64 64-byte `VPSHUFB` with a `VPTERNLOGD` fused accumulate
    /// (needs AVX-512F + AVX-512BW).
    Avx512,
    /// x86_64 `GF2P8AFFINEQB`: one affine transform per 64-byte product
    /// (needs GFNI + AVX-512F + AVX-512BW; VEX-only GFNI parts fall back
    /// to `avx2`).
    Gfni,
    /// AArch64 `TBL` (`vqtbl1q_u8`), 16 bytes/op.
    Neon,
}

impl Kernel {
    /// Every tier, fastest first.
    pub fn all() -> [Kernel; 6] {
        [Kernel::Gfni, Kernel::Avx512, Kernel::Avx2, Kernel::Neon, Kernel::Ssse3, Kernel::Scalar]
    }

    /// Best tier the running CPU supports.
    pub fn detect() -> Kernel {
        #[cfg(target_arch = "x86_64")]
        {
            let avx512 =
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw");
            if avx512 && is_x86_feature_detected!("gfni") {
                return Kernel::Gfni;
            }
            if avx512 {
                return Kernel::Avx512;
            }
            if is_x86_feature_detected!("avx2") {
                return Kernel::Avx2;
            }
            if is_x86_feature_detected!("ssse3") {
                return Kernel::Ssse3;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    }

    /// Can this tier run on the current CPU?
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => is_x86_feature_detected!("ssse3"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => {
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
            }
            #[cfg(target_arch = "x86_64")]
            Kernel::Gfni => {
                is_x86_feature_detected!("gfni")
                    && is_x86_feature_detected!("avx512f")
                    && is_x86_feature_detected!("avx512bw")
            }
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Ssse3 => "ssse3",
            Kernel::Avx2 => "avx2",
            Kernel::Avx512 => "avx512",
            Kernel::Gfni => "gfni",
            Kernel::Neon => "neon",
        }
    }

    /// The tier forced via `UNILRC_GF_KERNEL`, treated as *authoritative*:
    /// `None` when the variable is unset, empty, or `auto`; **panics** on
    /// an unknown or CPU-unsupported name. This is the strict reading the
    /// forced-kernel CI matrix needs — a broken tier must never be
    /// silently replaced by a fallback during tests.
    /// ([`GfEngine::from_env`] keeps the lenient fall-back-to-scalar
    /// reading for production configs.)
    pub fn forced_from_env() -> Option<Kernel> {
        let name = std::env::var("UNILRC_GF_KERNEL").ok()?;
        if name.is_empty() || name.eq_ignore_ascii_case("auto") {
            return None;
        }
        let k = Kernel::parse(&name)
            .unwrap_or_else(|| panic!("UNILRC_GF_KERNEL={name}: unknown tier"));
        assert!(k.available(), "UNILRC_GF_KERNEL={name}: tier unavailable on this CPU");
        Some(k)
    }

    /// Parse a tier name (`auto` resolves to [`Kernel::detect`]).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "swar" => Some(Kernel::Scalar),
            "ssse3" => Some(Kernel::Ssse3),
            "avx2" => Some(Kernel::Avx2),
            "avx512" | "avx512bw" => Some(Kernel::Avx512),
            "gfni" => Some(Kernel::Gfni),
            "neon" => Some(Kernel::Neon),
            "auto" => Some(Kernel::detect()),
            _ => None,
        }
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Default lane size for the striped executor: half an L2-ish working set,
/// so one lane's src+dst stay cache-resident while it is processed.
const DEFAULT_LANE: usize = 64 * 1024;

/// Minimum total bytes of input a call must touch before the worker pool is
/// engaged. Dispatch is a queue push + latch (~1 µs) now that workers are
/// persistent, so this sits far below the 2 MiB the scoped-spawn executor
/// needed to hide its ~tens-of-µs thread startup.
const DEFAULT_PAR_WORK: usize = 256 * 1024;

/// Adaptive batch chunking targets this many tasks per worker per wave:
/// enough slack for load balancing across uneven stripes, few enough that
/// a degraded burst doesn't flood the queue with lane-sized tasks.
const BATCH_TASKS_PER_WORKER: usize = 3;

/// A GF(2^8) execution engine: one kernel tier + striping parameters +
/// (for `threads > 1`) a persistent worker pool, created lazily on first
/// parallel call and frozen with the engine. Clones share the pool.
#[derive(Clone)]
pub struct GfEngine {
    kernel: Kernel,
    threads: usize,
    lane: usize,
    par_work: usize,
    /// Explicit batch task granularity (input bytes per pool task);
    /// `None` = adaptive (derived per batch from work vs. worker count).
    chunk: Option<usize>,
    pool: Arc<OnceLock<Arc<WorkPool>>>,
}

impl std::fmt::Debug for GfEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GfEngine")
            .field("kernel", &self.kernel)
            .field("threads", &self.threads)
            .field("lane", &self.lane)
            .field("par_work", &self.par_work)
            .field("chunk", &self.chunk)
            .field("pool_started", &self.pool.get().is_some())
            .finish()
    }
}

impl Default for GfEngine {
    fn default() -> Self {
        GfEngine::auto()
    }
}

impl GfEngine {
    /// Detected kernel, one worker per available core.
    pub fn auto() -> GfEngine {
        GfEngine::new(Kernel::detect())
            .with_threads(std::thread::available_parallelism().map_or(1, |n| n.get()))
    }

    /// Single-threaded portable baseline (the seed behaviour).
    pub fn scalar() -> GfEngine {
        GfEngine::new(Kernel::Scalar)
    }

    /// Engine on a specific tier; silently falls back to [`Kernel::Scalar`]
    /// if the tier is not available on this CPU, so a config written on one
    /// machine stays runnable on another.
    pub fn new(kernel: Kernel) -> GfEngine {
        let kernel = if kernel.available() { kernel } else { Kernel::Scalar };
        GfEngine {
            kernel,
            threads: 1,
            lane: DEFAULT_LANE,
            par_work: DEFAULT_PAR_WORK,
            chunk: None,
            pool: Arc::new(OnceLock::new()),
        }
    }

    /// Engine configured from the environment:
    /// `UNILRC_GF_KERNEL` (scalar|ssse3|avx2|avx512|gfni|neon|auto),
    /// `UNILRC_GF_THREADS`, `UNILRC_GF_LANE_KB`, `UNILRC_GF_PAR_KB`
    /// (striping work threshold), `UNILRC_GF_CHUNK_KB` (explicit batch
    /// task granularity; 0 = adaptive).
    pub fn from_env() -> GfEngine {
        let mut e = GfEngine::auto();
        if let Ok(k) = std::env::var("UNILRC_GF_KERNEL") {
            if let Some(k) = Kernel::parse(&k) {
                e = e.with_kernel(k);
            }
        }
        if let Ok(t) = std::env::var("UNILRC_GF_THREADS") {
            if let Ok(t) = t.parse::<usize>() {
                e = e.with_threads(t);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_LANE_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_lane(kb * 1024);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_PAR_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_par_work(kb * 1024);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_CHUNK_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_chunk(kb * 1024);
            }
        }
        e
    }

    pub fn with_kernel(mut self, kernel: Kernel) -> GfEngine {
        self.kernel = if kernel.available() { kernel } else { Kernel::Scalar };
        self
    }

    /// Set the worker count. Replaces any existing pool handle so the pool
    /// is (re)created at the new size on the next parallel call; the old
    /// pool's threads are joined when its last engine clone drops.
    pub fn with_threads(mut self, threads: usize) -> GfEngine {
        self.threads = threads.max(1);
        self.pool = Arc::new(OnceLock::new());
        self
    }

    pub fn with_lane(mut self, lane_bytes: usize) -> GfEngine {
        self.lane = lane_bytes.max(64);
        self
    }

    /// Lower the parallelism threshold (tests use this to exercise the
    /// striped path on small blocks).
    pub fn with_par_work(mut self, bytes: usize) -> GfEngine {
        self.par_work = bytes;
        self
    }

    /// Pin the batch task granularity to `bytes` of input work per pool
    /// task (`--gf-chunk-kb` / `UNILRC_GF_CHUNK_KB`); `0` restores the
    /// adaptive policy. The per-op output step is still floored at one
    /// lane, so an absurdly small value degrades to lane-sized tasks
    /// rather than sub-vector splinters.
    pub fn with_chunk(mut self, bytes: usize) -> GfEngine {
        self.chunk = (bytes > 0).then_some(bytes);
        self
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Striping work threshold in bytes (below it, calls run inline).
    pub fn par_work(&self) -> usize {
        self.par_work
    }

    /// Has the worker pool been started (first parallel call ran)?
    pub fn pool_started(&self) -> bool {
        self.pool.get().is_some()
    }

    /// One-line description for logs and `unilrc engine`.
    pub fn describe(&self) -> String {
        format!(
            "kernel={} threads={} lane={}KiB par_work={}KiB chunk={} pool={}",
            self.kernel,
            self.threads,
            self.lane / 1024,
            self.par_work / 1024,
            match self.chunk {
                Some(c) => format!("{}KiB", c.div_ceil(1024)),
                None => "adaptive".to_string(),
            },
            if self.threads <= 1 {
                "off"
            } else if self.pool_started() {
                "running"
            } else {
                "lazy"
            }
        )
    }

    /// Batch task granularity in input bytes per pool task, for a batch
    /// touching `work` total input bytes: the explicit `--gf-chunk-kb`
    /// override if set, otherwise `work / (workers × ~3)` rounded up to
    /// whole lanes — so a huge multi-stripe event lands ~2–4 tasks on each
    /// worker instead of thousands of lane-sized ones, while small events
    /// floor at one lane and keep their parallelism.
    pub fn batch_chunk(&self, work: usize) -> usize {
        if let Some(c) = self.chunk {
            return c;
        }
        let tasks = self.threads.max(1) * BATCH_TASKS_PER_WORKER;
        work.div_ceil(tasks).div_ceil(self.lane).max(1) * self.lane
    }

    /// Output bytes each pool task of a batched op produces, for an op
    /// reading `sources` input slices within a batch of `work` total input
    /// bytes: the batch granularity divided across the op's inputs, in
    /// whole lanes, floored at one lane. (Chunking is per-op: a batch of
    /// more stripes than workers still enqueues at least one task per
    /// stripe.)
    pub fn batch_step(&self, work: usize, sources: usize) -> usize {
        (self.batch_chunk(work) / (self.lane * sources.max(1))).max(1) * self.lane
    }

    /// The persistent pool, started on first use; `None` when the engine is
    /// single-threaded.
    fn pool(&self) -> Option<&WorkPool> {
        if self.threads <= 1 {
            return None;
        }
        Some(self.pool.get_or_init(|| Arc::new(WorkPool::new(self.threads))).as_ref())
    }

    // ------------------------------------------------------------ slice ops

    /// `dst ^= c · src` on the selected tier.
    pub fn mul_acc(&self, c: u8, src: &[u8], dst: &mut [u8]) {
        assert_eq!(dst.len(), src.len(), "mul_acc length mismatch");
        // The SWAR tier derives its plane constants from `c` directly —
        // don't build lookup tables it would never read.
        if self.kernel == Kernel::Scalar {
            return slice::mul_acc_slice_scalar(c, src, dst);
        }
        match c {
            0 => {}
            1 => self.xor(dst, src),
            _ => self.mul_acc_kernel(&NibbleTables::new(c), src, dst),
        }
    }

    /// `dst ^= c · src` with the coefficient's tables precomputed (the
    /// cached-plan hot path: no per-call table build).
    pub fn mul_acc_t(&self, t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        assert_eq!(dst.len(), src.len(), "mul_acc_t length mismatch");
        match t.c {
            0 => {}
            1 => self.xor(dst, src),
            _ => self.mul_acc_kernel(t, src, dst),
        }
    }

    fn mul_acc_kernel(&self, t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        // SAFETY: `GfEngine::new`/`with_kernel` only store tiers that
        // `Kernel::available()` confirmed on this CPU.
        match self.kernel {
            Kernel::Scalar => slice::mul_acc_slice_scalar(t.c, src, dst),
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => unsafe { super::simd::x86_64::mul_acc_ssse3(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::mul_acc_avx2(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => unsafe { super::simd::x86_64::mul_acc_avx512(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Gfni => unsafe { super::simd::x86_64::mul_acc_gfni(t, src, dst) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::mul_acc_neon(t, src, dst) },
            _ => slice::mul_acc_slice_scalar(t.c, src, dst),
        }
    }

    /// Fused `dst ^= c1 · src1 ^ c2 · src2`: one load + one store of `dst`
    /// per two source slices (the SIMD tiers read both products before
    /// touching `dst`), versus two full read-modify-write passes with
    /// back-to-back [`Self::mul_acc_t`]. This is the inner step of
    /// [`Self::matmul_blocks_t`], where `dst` traffic dominates once the
    /// tables are cached.
    pub fn mul_acc2_t(
        &self,
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        assert_eq!(dst.len(), src1.len(), "mul_acc2_t src1 length mismatch");
        assert_eq!(dst.len(), src2.len(), "mul_acc2_t src2 length mismatch");
        // A zero coefficient degenerates to the single-source op (which
        // also keeps the c=1 XOR fast path for the surviving source).
        if t1.c == 0 {
            return self.mul_acc_t(t2, src2, dst);
        }
        if t2.c == 0 {
            return self.mul_acc_t(t1, src1, dst);
        }
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => unsafe {
                super::simd::x86_64::mul_acc2_ssse3(t1, src1, t2, src2, dst)
            },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::mul_acc2_avx2(t1, src1, t2, src2, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => unsafe {
                super::simd::x86_64::mul_acc2_avx512(t1, src1, t2, src2, dst)
            },
            #[cfg(target_arch = "x86_64")]
            Kernel::Gfni => unsafe { super::simd::x86_64::mul_acc2_gfni(t1, src1, t2, src2, dst) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::mul_acc2_neon(t1, src1, t2, src2, dst) },
            _ => {
                slice::mul_acc_slice_scalar(t1.c, src1, dst);
                slice::mul_acc_slice_scalar(t2.c, src2, dst);
            }
        }
    }

    /// `dst ^= src` on the selected tier.
    pub fn xor(&self, dst: &mut [u8], src: &[u8]) {
        assert_eq!(dst.len(), src.len(), "xor length mismatch");
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::xor_avx2(dst, src) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 | Kernel::Gfni => unsafe { super::simd::x86_64::xor_avx512(dst, src) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::xor_neon(dst, src) },
            _ => slice::xor_slice_scalar(dst, src),
        }
    }

    // -------------------------------------------------------- striped ops

    /// Worker count for a call touching `block`-byte rows and `work` total
    /// input bytes; 1 means run inline.
    fn workers_for(&self, block: usize, work: usize) -> usize {
        if self.threads <= 1 || work < self.par_work || block < 2 * self.lane {
            1
        } else {
            self.threads.min(block.div_ceil(self.lane))
        }
    }

    /// `dst = srcs[0] ^ srcs[1] ^ …`, striped across the worker pool for
    /// large blocks (the UniLRC repair path).
    pub fn fold_blocks(&self, dst: &mut [u8], srcs: &[&[u8]]) {
        assert!(!srcs.is_empty(), "fold needs at least one source");
        for s in srcs {
            assert_eq!(s.len(), dst.len(), "fold length mismatch");
        }
        let block = dst.len();
        let workers = self.workers_for(block, block * srcs.len());
        let pool = if workers > 1 { self.pool() } else { None };
        let Some(pool) = pool else {
            dst.copy_from_slice(srcs[0]);
            for s in &srcs[1..] {
                self.xor(dst, s);
            }
            return;
        };
        let lane = self.lane;
        // Group whole lanes into one task per worker; within a task, each
        // lane is copied and folded before the next so src+dst stay
        // cache-resident.
        let per = block.div_ceil(lane).div_ceil(workers).max(1) * lane;
        pool.scope(|scope| {
            let mut off = 0usize;
            for chunk in dst.chunks_mut(per) {
                let base = off;
                off += chunk.len();
                scope.submit(move || {
                    for (l, c) in chunk.chunks_mut(lane).enumerate() {
                        let o = base + l * lane;
                        let w = c.len();
                        c.copy_from_slice(&srcs[0][o..o + w]);
                        for s in &srcs[1..] {
                            self.xor(c, &s[o..o + w]);
                        }
                    }
                });
            }
        });
    }

    /// Matrix-style coding primitive: `outs[i] = ⊕_j coeff[i][j] · srcs[j]`,
    /// striped across the worker pool. Each task owns a disjoint byte range
    /// of every output row and walks it source-major, so one cache-resident
    /// lane of each source is scattered into all rows before moving on.
    pub fn matmul_blocks(&self, coeff: &[&[u8]], srcs: &[&[u8]], outs: &mut [Vec<u8>]) {
        let tables = NibbleTables::for_rows(coeff.iter().copied());
        self.matmul_blocks_t(&tables, srcs, outs);
    }

    /// [`Self::matmul_blocks`] with per-coefficient tables prebuilt — the
    /// entry point for cached decode plans.
    pub fn matmul_blocks_t(
        &self,
        tables: &[Vec<NibbleTables>],
        srcs: &[&[u8]],
        outs: &mut [Vec<u8>],
    ) {
        assert_eq!(tables.len(), outs.len(), "row count mismatch");
        let block = srcs.first().map_or(0, |s| s.len());
        for (row, out) in tables.iter().zip(outs.iter_mut()) {
            assert_eq!(row.len(), srcs.len(), "column count mismatch");
            assert_eq!(out.len(), block, "output block size mismatch");
        }
        let workers = self.workers_for(block, block * srcs.len() * outs.len().max(1));
        let pool = if workers > 1 && !outs.is_empty() { self.pool() } else { None };
        let Some(pool) = pool else {
            let mut full: Vec<&mut [u8]> = outs.iter_mut().map(|o| o.as_mut_slice()).collect();
            self.matmul_lane(tables, srcs, 0, &mut full);
            return;
        };
        let lane = self.lane;
        let nlanes = block.div_ceil(lane);
        // Transpose row-major chunking into lane-major work items: lane l
        // holds the l-th chunk of every output row (disjoint &mut borrows).
        let mut row_chunks: Vec<_> = outs.iter_mut().map(|o| o.chunks_mut(lane)).collect();
        let mut lanes: Vec<(usize, Vec<&mut [u8]>)> = Vec::with_capacity(nlanes);
        for l in 0..nlanes {
            let chunk: Vec<&mut [u8]> =
                row_chunks.iter_mut().map(|it| it.next().expect("lane chunk")).collect();
            lanes.push((l * lane, chunk));
        }
        let per = nlanes.div_ceil(workers);
        pool.scope(|scope| {
            while !lanes.is_empty() {
                let mut group: Vec<_> = lanes.drain(..per.min(lanes.len())).collect();
                scope.submit(move || {
                    for (off, louts) in group.iter_mut() {
                        self.matmul_lane(tables, srcs, *off, louts);
                    }
                });
            }
        });
    }

    /// One lane of the matmul: outputs are the `[off..off+w)` sub-slices of
    /// the full rows; sources are indexed with the same offset. Sources are
    /// consumed in fused pairs ([`Self::mul_acc2_t`]) so each output lane
    /// is loaded/stored once per *two* sources.
    fn matmul_lane(
        &self,
        tables: &[Vec<NibbleTables>],
        srcs: &[&[u8]],
        off: usize,
        louts: &mut [&mut [u8]],
    ) {
        for out in louts.iter_mut() {
            out.fill(0);
        }
        let mut j = 0;
        while j + 1 < srcs.len() {
            for (row, out) in tables.iter().zip(louts.iter_mut()) {
                let w = out.len();
                self.mul_acc2_t(
                    &row[j],
                    &srcs[j][off..off + w],
                    &row[j + 1],
                    &srcs[j + 1][off..off + w],
                    out,
                );
            }
            j += 2;
        }
        if j < srcs.len() {
            for (row, out) in tables.iter().zip(louts.iter_mut()) {
                let w = out.len();
                self.mul_acc_t(&row[j], &srcs[j][off..off + w], out);
            }
        }
    }

    // -------------------------------------------------------- batched ops

    /// Apply one coefficient-table matrix to many stripes in a single
    /// batched wave: `result[s][i] = ⊕_j tables[i][j] · stripes[s][j]`.
    /// This is the shared engine for `Code::encode_stripes`,
    /// `DecodePlan::execute_batch`, and `CachedPlan::execute_batch`.
    /// Output buffers come from the block pool (callers may
    /// [`recycle`](super::pool::recycle) them); every byte is overwritten.
    pub fn matmul_stripes_t(
        &self,
        tables: &[Vec<NibbleTables>],
        stripes: &[Vec<&[u8]>],
    ) -> Vec<Vec<Vec<u8>>> {
        let mut all: Vec<Vec<Vec<u8>>> = stripes
            .iter()
            .map(|sources| {
                let len = sources.first().map_or(0, |s| s.len());
                (0..tables.len()).map(|_| super::pool::take_for_overwrite(len)).collect()
            })
            .collect();
        let work: usize =
            stripes.iter().map(|s| s.iter().map(|b| b.len()).sum::<usize>()).sum::<usize>();
        self.batch(work, |b| {
            for (sources, outs) in stripes.iter().zip(all.iter_mut()) {
                b.matmul_t(tables, sources.clone(), outs);
            }
        });
        all
    }

    /// Run a *batch* of coding operations as one pool submission wave:
    /// `f` receives a [`CodingBatch`] and enqueues any number of folds /
    /// matmuls (typically one per stripe of a recovery or read event); all
    /// of them have completed when `batch` returns. `work` is the total
    /// input bytes the batch will touch — below the engine's striping
    /// threshold (or on a single-threaded engine) the ops run inline in
    /// submission order instead of through the pool.
    ///
    /// This is how multi-stripe events beat the per-call striping gate on
    /// small blocks: a 64 KiB block is too small to stripe by itself, but
    /// 40 stripes × 64 KiB submitted together keep every worker busy.
    pub fn batch<'env, R, F>(&'env self, work: usize, f: F) -> R
    where
        F: for<'scope> FnOnce(&mut CodingBatch<'scope, 'env>) -> R,
    {
        let chunk = self.batch_chunk(work);
        let pool = if self.threads > 1 && work >= self.par_work { self.pool() } else { None };
        match pool {
            Some(pool) => pool.scope(|scope| {
                let mut b = CodingBatch { engine: self, scope: Some(scope), chunk };
                f(&mut b)
            }),
            None => {
                let mut b = CodingBatch { engine: self, scope: None, chunk };
                f(&mut b)
            }
        }
    }
}

/// A batch of coding operations submitted to the engine's worker pool in
/// one wave (see [`GfEngine::batch`]). Ops enqueued here do **not** run
/// eagerly — they complete by the time `batch` returns. Each op is split
/// into lane-sized tasks so the pool load-balances across stripes.
pub struct CodingBatch<'scope, 'env: 'scope> {
    engine: &'env GfEngine,
    /// `None` ⇒ run ops inline (single-threaded engine or tiny batch).
    scope: Option<&'scope BatchScope<'scope, 'env>>,
    /// Input-work granularity per pool task for this batch, fixed when the
    /// batch opened (adaptive or the `--gf-chunk-kb` override).
    chunk: usize,
}

impl<'scope, 'env> CodingBatch<'scope, 'env> {
    /// Output bytes per task for an op reading `sources` slices: the batch
    /// granularity spread across the op's inputs, whole lanes, floored at
    /// one lane (mirrors [`GfEngine::batch_step`]).
    fn step(&self, sources: usize) -> usize {
        (self.chunk / (self.engine.lane * sources.max(1))).max(1) * self.engine.lane
    }

    /// Enqueue an arbitrary engine task (advanced callers).
    pub fn submit<F>(&mut self, f: F)
    where
        F: FnOnce(&GfEngine) + Send + 'env,
    {
        let engine = self.engine;
        match self.scope {
            None => f(engine),
            Some(scope) => scope.submit(move || f(engine)),
        }
    }

    /// Enqueue `dst = srcs[0] ^ srcs[1] ^ …` (XOR-local repair of one
    /// stripe within a batched event).
    pub fn fold(&mut self, dst: &'env mut [u8], srcs: Vec<&'env [u8]>) {
        assert!(!srcs.is_empty(), "fold needs at least one source");
        for s in &srcs {
            assert_eq!(s.len(), dst.len(), "fold length mismatch");
        }
        let engine = self.engine;
        let Some(scope) = self.scope else {
            dst.copy_from_slice(srcs[0]);
            for s in &srcs[1..] {
                engine.xor(dst, s);
            }
            return;
        };
        let step = self.step(srcs.len());
        let lane = engine.lane;
        // One shared allocation for the source list; tasks clone the Arc.
        let srcs = Arc::new(srcs);
        let mut off = 0usize;
        for c in dst.chunks_mut(step) {
            let base = off;
            off += c.len();
            let srcs = Arc::clone(&srcs);
            // Within a task, copy + fold one lane at a time so src+dst
            // stay cache-resident however large the task's span is.
            scope.submit(move || {
                for (l, sub) in c.chunks_mut(lane).enumerate() {
                    let o = base + l * lane;
                    let w = sub.len();
                    sub.copy_from_slice(&srcs[0][o..o + w]);
                    for s in &srcs[1..] {
                        engine.xor(sub, &s[o..o + w]);
                    }
                }
            });
        }
    }

    /// Enqueue `outs[i] = ⊕_j tables[i][j] · srcs[j]` (one stripe's encode
    /// or decode within a batched event). `tables` must outlive the batch —
    /// build them once and share them across every stripe of the event.
    pub fn matmul_t(
        &mut self,
        tables: &'env [Vec<NibbleTables>],
        srcs: Vec<&'env [u8]>,
        outs: &'env mut [Vec<u8>],
    ) {
        assert_eq!(tables.len(), outs.len(), "row count mismatch");
        let block = srcs.first().map_or(0, |s| s.len());
        for (row, out) in tables.iter().zip(outs.iter_mut()) {
            assert_eq!(row.len(), srcs.len(), "column count mismatch");
            assert_eq!(out.len(), block, "output block size mismatch");
        }
        let engine = self.engine;
        let Some(scope) = self.scope else {
            let mut full: Vec<&mut [u8]> = outs.iter_mut().map(|o| o.as_mut_slice()).collect();
            engine.matmul_lane(tables, &srcs, 0, &mut full);
            return;
        };
        if outs.is_empty() {
            return;
        }
        let step = self.step(srcs.len());
        let lane = engine.lane;
        let ntasks = block.div_ceil(step);
        // One shared allocation for the source list; tasks clone the Arc.
        let srcs = Arc::new(srcs);
        let mut row_chunks: Vec<_> = outs.iter_mut().map(|o| o.chunks_mut(step)).collect();
        for t in 0..ntasks {
            let mut louts: Vec<&mut [u8]> =
                row_chunks.iter_mut().map(|it| it.next().expect("task chunk")).collect();
            let srcs = Arc::clone(&srcs);
            let off = t * step;
            // Within a task, run the matmul one lane at a time so each
            // output window stays cache-resident across the fused source
            // pairs, however large the task's span is.
            scope.submit(move || {
                let nsub = louts.first().map_or(0, |o| o.len().div_ceil(lane));
                let mut subs: Vec<_> = louts.iter_mut().map(|o| o.chunks_mut(lane)).collect();
                for s in 0..nsub {
                    let mut lane_outs: Vec<&mut [u8]> =
                        subs.iter_mut().map(|it| it.next().expect("lane chunk")).collect();
                    engine.matmul_lane(tables, &srcs, off + s * lane, &mut lane_outs);
                }
            });
        }
    }
}

static GLOBAL: OnceLock<GfEngine> = OnceLock::new();

/// The process-wide engine. First use freezes it: initialized from the
/// environment ([`GfEngine::from_env`]) unless [`install`] ran earlier.
pub fn engine() -> &'static GfEngine {
    GLOBAL.get_or_init(GfEngine::from_env)
}

/// Install a specific engine as the process-wide one (CLI `--gf-kernel` /
/// config `[experiment] gf_kernel`). Returns `false` if the engine was
/// already initialized — the caller should warn that the override is late.
pub fn install(e: GfEngine) -> bool {
    GLOBAL.set(e).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::gf_mul;
    use crate::prng::Prng;

    fn available_kernels() -> Vec<Kernel> {
        Kernel::all().into_iter().filter(|k| k.available()).collect()
    }

    #[test]
    fn detect_is_available() {
        assert!(Kernel::detect().available());
    }

    #[test]
    fn parse_roundtrip() {
        for k in Kernel::all() {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert!(Kernel::parse("auto").is_some());
        assert_eq!(Kernel::parse("nope"), None);
    }

    #[test]
    fn unavailable_kernel_falls_back_to_scalar() {
        // At most one of AVX2/NEON exists on any one machine, so whichever
        // is foreign must clamp to scalar rather than crash later.
        for k in Kernel::all() {
            let e = GfEngine::new(k);
            assert!(e.kernel().available());
        }
    }

    #[test]
    fn every_tier_matches_reference_mul_acc() {
        let mut p = Prng::new(17);
        let src = p.bytes(1000);
        let init = p.bytes(1000);
        for k in available_kernels() {
            let e = GfEngine::new(k);
            for c in [0u8, 1, 2, 0x53, 0xFF] {
                let mut dst = init.clone();
                e.mul_acc(c, &src, &mut dst);
                let expect: Vec<u8> =
                    init.iter().zip(&src).map(|(&d, &s)| d ^ gf_mul(c, s)).collect();
                assert_eq!(dst, expect, "kernel={k} c={c}");
            }
        }
    }

    #[test]
    fn mul_acc2_matches_two_single_ops() {
        let mut p = Prng::new(23);
        // straddle the vector widths and exercise the scalar tail
        for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 64, 1000] {
            let s1 = p.bytes(len);
            let s2 = p.bytes(len);
            let init = p.bytes(len);
            for k in available_kernels() {
                let e = GfEngine::new(k);
                for (c1, c2) in [(0u8, 0u8), (0, 7), (1, 1), (1, 0x53), (2, 3), (0x53, 0xFF)] {
                    let (t1, t2) = (NibbleTables::new(c1), NibbleTables::new(c2));
                    let mut fused = init.clone();
                    e.mul_acc2_t(&t1, &s1, &t2, &s2, &mut fused);
                    let mut seq = init.clone();
                    e.mul_acc_t(&t1, &s1, &mut seq);
                    e.mul_acc_t(&t2, &s2, &mut seq);
                    assert_eq!(fused, seq, "kernel={k} c1={c1} c2={c2} len={len}");
                }
            }
        }
    }

    #[test]
    fn striped_matmul_matches_serial() {
        let mut p = Prng::new(18);
        let block = 10_000; // not a lane multiple: exercises the short tail lane
        let srcs: Vec<Vec<u8>> = (0..5).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let rows: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(5)).collect();
        let rrefs: Vec<&[u8]> = rows.iter().map(|v| v.as_slice()).collect();

        let serial = GfEngine::scalar();
        let mut expect = vec![vec![0u8; block]; 3];
        serial.matmul_blocks(&rrefs, &refs, &mut expect);

        for k in available_kernels() {
            let par = GfEngine::new(k).with_threads(4).with_lane(1024).with_par_work(0);
            let mut got = vec![vec![1u8; block]; 3]; // nonzero: checks overwrite
            par.matmul_blocks(&rrefs, &refs, &mut got);
            assert_eq!(got, expect, "kernel={k}");
        }
    }

    #[test]
    fn striped_fold_matches_serial() {
        let mut p = Prng::new(19);
        let block = 7777;
        let srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut expect = vec![0u8; block];
        GfEngine::scalar().fold_blocks(&mut expect, &refs);
        for k in available_kernels() {
            let par = GfEngine::new(k).with_threads(3).with_lane(512).with_par_work(0);
            let mut got = vec![9u8; block];
            par.fold_blocks(&mut got, &refs);
            assert_eq!(got, expect, "kernel={k}");
        }
    }

    #[test]
    fn empty_matmul_ok() {
        let mut outs: Vec<Vec<u8>> = vec![];
        GfEngine::auto().matmul_blocks(&[], &[], &mut outs);
        assert!(outs.is_empty());
    }

    #[test]
    fn pool_is_lazy_and_reused_across_calls() {
        let mut p = Prng::new(20);
        let e = GfEngine::new(Kernel::detect()).with_threads(2).with_lane(256).with_par_work(0);
        assert!(!e.pool_started(), "pool must not start before a parallel call");
        let srcs: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(4096)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u8; 4096];
        e.fold_blocks(&mut out, &refs);
        assert!(e.pool_started());
        let clone = e.clone();
        assert!(clone.pool_started(), "clones share the started pool");
    }

    #[test]
    fn adaptive_chunk_scales_with_work_and_floors_at_lane() {
        let e = GfEngine::new(Kernel::Scalar).with_threads(2).with_lane(4096);
        // tiny or empty batches floor at one lane
        assert_eq!(e.batch_chunk(0), 4096);
        assert_eq!(e.batch_chunk(100), 4096);
        // large batches land ~2–4 tasks per worker, in whole lanes
        let work = 60 * 4096 * 6;
        let chunk = e.batch_chunk(work);
        assert_eq!(chunk % 4096, 0);
        let tasks = work.div_ceil(chunk);
        assert!((2..=8).contains(&tasks), "tasks={tasks} for 2 workers");
        // explicit override wins at any work size; 0 restores adaptive
        let o = e.clone().with_chunk(12345);
        assert_eq!(o.batch_chunk(1 << 30), 12345);
        assert_eq!(o.with_chunk(0).batch_chunk(0), 4096);
    }

    #[test]
    fn batch_step_spreads_chunk_across_sources_with_lane_floor() {
        let e = GfEngine::new(Kernel::Scalar).with_threads(2).with_lane(1024).with_chunk(64);
        // absurdly small explicit chunk: per-task output is still one lane
        assert_eq!(e.batch_step(1 << 20, 8), 1024);
        let e = e.with_chunk(1 << 20);
        let step = e.batch_step(1 << 20, 4);
        assert_eq!(step % 1024, 0);
        assert_eq!(step, (1 << 20) / (1024 * 4) * 1024);
    }

    #[test]
    fn batch_matches_sequential_ops() {
        let mut p = Prng::new(21);
        let block = 3000;
        let stripes = 5;
        let all_srcs: Vec<Vec<Vec<u8>>> =
            (0..stripes).map(|_| (0..4).map(|_| p.bytes(block)).collect()).collect();
        let coeff: Vec<Vec<u8>> = (0..2).map(|_| p.bytes(4)).collect();
        let tables: Vec<Vec<NibbleTables>> = coeff
            .iter()
            .map(|row| row.iter().map(|&c| NibbleTables::new(c)).collect())
            .collect();

        let serial = GfEngine::scalar();
        let crefs: Vec<&[u8]> = coeff.iter().map(|v| v.as_slice()).collect();
        let mut expect: Vec<Vec<Vec<u8>>> = Vec::new();
        for srcs in &all_srcs {
            let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let mut outs = vec![vec![0u8; block]; 2];
            serial.matmul_blocks(&crefs, &refs, &mut outs);
            expect.push(outs);
        }

        for threads in [1usize, 2, 8] {
            let e = GfEngine::new(Kernel::detect())
                .with_threads(threads)
                .with_lane(512)
                .with_par_work(0);
            let mut got: Vec<Vec<Vec<u8>>> = vec![vec![vec![7u8; block]; 2]; stripes];
            e.batch(stripes * 4 * block, |b| {
                for (srcs, outs) in all_srcs.iter().zip(got.iter_mut()) {
                    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                    b.matmul_t(&tables, refs, outs);
                }
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn batch_fold_matches_sequential() {
        let mut p = Prng::new(22);
        let block = 2049;
        let stripes = 4;
        let all_srcs: Vec<Vec<Vec<u8>>> =
            (0..stripes).map(|_| (0..5).map(|_| p.bytes(block)).collect()).collect();
        let mut expect: Vec<Vec<u8>> = Vec::new();
        for srcs in &all_srcs {
            let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0u8; block];
            GfEngine::scalar().fold_blocks(&mut out, &refs);
            expect.push(out);
        }
        for threads in [1usize, 2, 8] {
            let e = GfEngine::new(Kernel::detect())
                .with_threads(threads)
                .with_lane(512)
                .with_par_work(0);
            let mut got: Vec<Vec<u8>> = vec![vec![3u8; block]; stripes];
            e.batch(stripes * 5 * block, |b| {
                for (srcs, out) in all_srcs.iter().zip(got.iter_mut()) {
                    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                    b.fold(out, refs);
                }
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }
}
