//! Runtime-dispatched, parallel-striped GF(2^8) engine.
//!
//! [`Kernel`] is the instruction-set tier (detected once at startup,
//! overridable via `UNILRC_GF_KERNEL` / `--gf-kernel`); [`GfEngine`] bundles
//! a kernel with a striped parallel executor that splits large blocks into
//! cache-sized lanes and fans them across a persistent [`WorkPool`]
//! (`gf/workpool.rs`) — workers are spawned once per engine and reused by
//! every call, so dispatch costs a queue push instead of a thread spawn.
//! All tiers and both execution modes produce byte-identical results —
//! GF(2^8) is exact and XOR-accumulation is order-independent
//! (`tests/gf_simd.rs` asserts this differentially).
//!
//! Beyond the per-call striped entry points ([`GfEngine::matmul_blocks`],
//! [`GfEngine::fold_blocks`]), the engine exposes a *batched* mode:
//! [`GfEngine::batch`] opens a [`CodingBatch`] into which whole multi-stripe
//! events (full-node recovery, degraded-read fan-outs, bulk ingest) enqueue
//! every stripe's combine at once; the pool schedules tasks across stripes,
//! so small blocks that are below the intra-block striping threshold still
//! parallelize across the event (`tests/batch.rs`). Task granularity is
//! *adaptive* ([`GfEngine::batch_chunk`]): derived per batch from total
//! work vs. worker count (~2–4 tasks per worker per wave, floored at the
//! lane size), so a degraded burst of thousands of stripes no longer
//! floods the queue with lane-sized tasks; `--gf-chunk-kb` /
//! `UNILRC_GF_CHUNK_KB` pins it explicitly (`tests/chunking.rs`).
//!
//! The engine also owns the *memory-system* policy on top of the kernels:
//! outputs whose span exceeds a configurable LLC-sized threshold
//! (`--gf-nt-kb` / `UNILRC_GF_NT_KB`, auto-detected from `/sys`) are
//! written with **streaming (non-temporal) stores** — accumulation happens
//! in a cache-resident pooled scratch and the final pass fuses the last
//! source into one pure-store sweep, so a >LLC output is written to DRAM
//! exactly once with no read-for-ownership and no cache pollution. Workers
//! can optionally be **pinned** to distinct CPUs (`--gf-pin` /
//! `UNILRC_GF_PIN`) so a stripe's lanes stay on one socket, and batches
//! **merge** small same-batch ops into shared pool tasks
//! (`UNILRC_GF_MERGE`) so a burst of stripes ≫ workers fuses below one
//! task per stripe.
//!
//! The process-wide engine ([`engine`]) backs the hot-path entry points in
//! [`super::slice`], so every encode / repair / decode in the repo runs at
//! the selected tier without call sites knowing about dispatch.

use super::slice::{self, NibbleTables};
use super::workpool::{BatchScope, WorkPool};
use std::sync::{Arc, OnceLock};

/// Instruction-set tier of the multiply-accumulate kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable SWAR bit-plane loop (`u64` registers) — always available.
    Scalar,
    /// x86_64 `PSHUFB` split-nibble lookups, 16 bytes/op.
    Ssse3,
    /// x86_64 `VPSHUFB`, 32 bytes/op.
    Avx2,
    /// x86_64 64-byte `VPSHUFB` with a `VPTERNLOGD` fused accumulate
    /// (needs AVX-512F + AVX-512BW).
    Avx512,
    /// x86_64 `GF2P8AFFINEQB`: one affine transform per 64-byte product
    /// (needs GFNI + AVX-512F + AVX-512BW; VEX-only GFNI parts fall back
    /// to `avx2`).
    Gfni,
    /// AArch64 `TBL` (`vqtbl1q_u8`), 16 bytes/op.
    Neon,
}

impl Kernel {
    /// Every tier, fastest first.
    pub fn all() -> [Kernel; 6] {
        [Kernel::Gfni, Kernel::Avx512, Kernel::Avx2, Kernel::Neon, Kernel::Ssse3, Kernel::Scalar]
    }

    /// Best tier the running CPU supports.
    pub fn detect() -> Kernel {
        #[cfg(target_arch = "x86_64")]
        {
            let avx512 =
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw");
            if avx512 && is_x86_feature_detected!("gfni") {
                return Kernel::Gfni;
            }
            if avx512 {
                return Kernel::Avx512;
            }
            if is_x86_feature_detected!("avx2") {
                return Kernel::Avx2;
            }
            if is_x86_feature_detected!("ssse3") {
                return Kernel::Ssse3;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    }

    /// Can this tier run on the current CPU?
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => is_x86_feature_detected!("ssse3"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => {
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
            }
            #[cfg(target_arch = "x86_64")]
            Kernel::Gfni => {
                is_x86_feature_detected!("gfni")
                    && is_x86_feature_detected!("avx512f")
                    && is_x86_feature_detected!("avx512bw")
            }
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Ssse3 => "ssse3",
            Kernel::Avx2 => "avx2",
            Kernel::Avx512 => "avx512",
            Kernel::Gfni => "gfni",
            Kernel::Neon => "neon",
        }
    }

    /// The tier forced via `UNILRC_GF_KERNEL`, treated as *authoritative*:
    /// `None` when the variable is unset, empty, or `auto`; **panics** on
    /// an unknown or CPU-unsupported name. This is the strict reading the
    /// forced-kernel CI matrix needs — a broken tier must never be
    /// silently replaced by a fallback during tests.
    /// ([`GfEngine::from_env`] keeps the lenient fall-back-to-scalar
    /// reading for production configs.)
    pub fn forced_from_env() -> Option<Kernel> {
        let name = std::env::var("UNILRC_GF_KERNEL").ok()?;
        if name.is_empty() || name.eq_ignore_ascii_case("auto") {
            return None;
        }
        let k = Kernel::parse(&name)
            .unwrap_or_else(|| panic!("UNILRC_GF_KERNEL={name}: unknown tier"));
        assert!(k.available(), "UNILRC_GF_KERNEL={name}: tier unavailable on this CPU");
        Some(k)
    }

    /// Parse a tier name (`auto` resolves to [`Kernel::detect`]).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "swar" => Some(Kernel::Scalar),
            "ssse3" => Some(Kernel::Ssse3),
            "avx2" => Some(Kernel::Avx2),
            "avx512" | "avx512bw" => Some(Kernel::Avx512),
            "gfni" => Some(Kernel::Gfni),
            "neon" => Some(Kernel::Neon),
            "auto" => Some(Kernel::detect()),
            _ => None,
        }
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Default lane size for the striped executor: half an L2-ish working set,
/// so one lane's src+dst stay cache-resident while it is processed.
const DEFAULT_LANE: usize = 64 * 1024;

/// Minimum total bytes of input a call must touch before the worker pool is
/// engaged. Dispatch is a queue push + latch (~1 µs) now that workers are
/// persistent, so this sits far below the 2 MiB the scoped-spawn executor
/// needed to hide its ~tens-of-µs thread startup.
const DEFAULT_PAR_WORK: usize = 256 * 1024;

/// Adaptive batch chunking targets this many tasks per worker per wave:
/// enough slack for load balancing across uneven stripes, few enough that
/// a degraded burst doesn't flood the queue with lane-sized tasks.
const BATCH_TASKS_PER_WORKER: usize = 3;

/// A GF(2^8) execution engine: one kernel tier + striping parameters +
/// memory-system policy (streaming-store threshold, worker pinning, task
/// merging) + (for `threads > 1`) a persistent worker pool, created lazily
/// on first parallel call and frozen with the engine. Clones share the
/// pool.
#[derive(Clone)]
pub struct GfEngine {
    kernel: Kernel,
    threads: usize,
    lane: usize,
    par_work: usize,
    /// Explicit batch task granularity (input bytes per pool task);
    /// `None` = adaptive (derived per batch from work vs. worker count).
    chunk: Option<usize>,
    /// Output-span threshold in bytes above which ops use streaming
    /// (non-temporal) stores: `0` forces them on, `usize::MAX` off, and
    /// the default is the detected LLC size.
    nt: usize,
    /// Pin workers to distinct CPUs, package-major (see `gf/topo.rs`).
    pin: bool,
    /// Fuse small same-batch ops into shared pool tasks.
    merge: bool,
    pool: Arc<OnceLock<Arc<WorkPool>>>,
}

impl std::fmt::Debug for GfEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GfEngine")
            .field("kernel", &self.kernel)
            .field("threads", &self.threads)
            .field("lane", &self.lane)
            .field("par_work", &self.par_work)
            .field("chunk", &self.chunk)
            .field("nt", &self.nt)
            .field("pin", &self.pin)
            .field("merge", &self.merge)
            .field("pool_started", &self.pool.get().is_some())
            .finish()
    }
}

/// Parse a streaming-store threshold spec in KiB (`--gf-nt-kb` /
/// `UNILRC_GF_NT_KB` / config `gf_nt_kb`): a number (`0` = stream every
/// output), `off`/`inf` to disable streaming entirely, `auto` for the
/// detected LLC size. Returns threshold **bytes**.
pub fn parse_nt_kb(s: &str) -> Option<usize> {
    let s = s.trim();
    if s.eq_ignore_ascii_case("off") || s.eq_ignore_ascii_case("inf") {
        return Some(usize::MAX);
    }
    if s.eq_ignore_ascii_case("auto") {
        return Some(super::topo::llc_bytes());
    }
    s.parse::<usize>().ok().map(|kb| kb.saturating_mul(1024))
}

impl Default for GfEngine {
    fn default() -> Self {
        GfEngine::auto()
    }
}

impl GfEngine {
    /// Detected kernel, one worker per available core.
    pub fn auto() -> GfEngine {
        GfEngine::new(Kernel::detect())
            .with_threads(std::thread::available_parallelism().map_or(1, |n| n.get()))
    }

    /// Single-threaded portable baseline (the seed behaviour).
    pub fn scalar() -> GfEngine {
        GfEngine::new(Kernel::Scalar)
    }

    /// Engine on a specific tier; silently falls back to [`Kernel::Scalar`]
    /// if the tier is not available on this CPU, so a config written on one
    /// machine stays runnable on another.
    pub fn new(kernel: Kernel) -> GfEngine {
        let kernel = if kernel.available() { kernel } else { Kernel::Scalar };
        GfEngine {
            kernel,
            threads: 1,
            lane: DEFAULT_LANE,
            par_work: DEFAULT_PAR_WORK,
            chunk: None,
            nt: super::topo::llc_bytes(),
            pin: false,
            merge: true,
            pool: Arc::new(OnceLock::new()),
        }
    }

    /// Engine configured from the environment:
    /// `UNILRC_GF_KERNEL` (scalar|ssse3|avx2|avx512|gfni|neon|auto),
    /// `UNILRC_GF_THREADS`, `UNILRC_GF_LANE_KB`, `UNILRC_GF_PAR_KB`
    /// (striping work threshold), `UNILRC_GF_CHUNK_KB` (explicit batch
    /// task granularity; 0 = adaptive), `UNILRC_GF_NT_KB`
    /// (streaming-store threshold; 0 = always, off/inf = never,
    /// auto = detected LLC), `UNILRC_GF_PIN` (pin workers to CPUs), and
    /// `UNILRC_GF_MERGE` (0 disables cross-op batch task merging).
    pub fn from_env() -> GfEngine {
        let mut e = GfEngine::auto();
        if let Ok(k) = std::env::var("UNILRC_GF_KERNEL") {
            if let Some(k) = Kernel::parse(&k) {
                e = e.with_kernel(k);
            }
        }
        if let Ok(t) = std::env::var("UNILRC_GF_THREADS") {
            if let Ok(t) = t.parse::<usize>() {
                e = e.with_threads(t);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_LANE_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_lane(kb * 1024);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_PAR_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_par_work(kb * 1024);
            }
        }
        if let Ok(kb) = std::env::var("UNILRC_GF_CHUNK_KB") {
            if let Ok(kb) = kb.parse::<usize>() {
                e = e.with_chunk(kb * 1024);
            }
        }
        if let Ok(v) = std::env::var("UNILRC_GF_NT_KB") {
            if let Some(bytes) = parse_nt_kb(&v) {
                e = e.with_nt(bytes);
            }
        }
        if let Ok(v) = std::env::var("UNILRC_GF_PIN") {
            e = e.with_pin(matches!(v.trim(), "1" | "true" | "on" | "yes"));
        }
        if let Ok(v) = std::env::var("UNILRC_GF_MERGE") {
            e = e.with_merge(!matches!(v.trim(), "0" | "false" | "off" | "no"));
        }
        e
    }

    pub fn with_kernel(mut self, kernel: Kernel) -> GfEngine {
        self.kernel = if kernel.available() { kernel } else { Kernel::Scalar };
        self
    }

    /// Set the worker count. Replaces any existing pool handle so the pool
    /// is (re)created at the new size on the next parallel call; the old
    /// pool's threads are joined when its last engine clone drops.
    pub fn with_threads(mut self, threads: usize) -> GfEngine {
        self.threads = threads.max(1);
        self.pool = Arc::new(OnceLock::new());
        self
    }

    pub fn with_lane(mut self, lane_bytes: usize) -> GfEngine {
        self.lane = lane_bytes.max(64);
        self
    }

    /// Lower the parallelism threshold (tests use this to exercise the
    /// striped path on small blocks).
    pub fn with_par_work(mut self, bytes: usize) -> GfEngine {
        self.par_work = bytes;
        self
    }

    /// Pin the batch task granularity to `bytes` of input work per pool
    /// task (`--gf-chunk-kb` / `UNILRC_GF_CHUNK_KB`); `0` restores the
    /// adaptive policy. The per-op output step is still floored at one
    /// lane, so an absurdly small value degrades to lane-sized tasks
    /// rather than sub-vector splinters.
    pub fn with_chunk(mut self, bytes: usize) -> GfEngine {
        self.chunk = (bytes > 0).then_some(bytes);
        self
    }

    /// Set the streaming-store threshold in **bytes** of output span:
    /// `0` streams every output, `usize::MAX` disables streaming (see
    /// [`parse_nt_kb`] for the KiB-spec grammar the CLI/env use).
    pub fn with_nt(mut self, threshold_bytes: usize) -> GfEngine {
        self.nt = threshold_bytes;
        self
    }

    /// Pin pool workers to distinct CPUs (package-major, so a stripe's
    /// lanes share a socket). Replaces any existing pool handle so the
    /// next parallel call creates a pinned pool.
    pub fn with_pin(mut self, pin: bool) -> GfEngine {
        self.pin = pin;
        self.pool = Arc::new(OnceLock::new());
        self
    }

    /// Enable/disable cross-op task merging in batches.
    pub fn with_merge(mut self, merge: bool) -> GfEngine {
        self.merge = merge;
        self
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Streaming-store threshold in bytes (`usize::MAX` = disabled).
    pub fn nt_threshold(&self) -> usize {
        self.nt
    }

    /// Are pool workers pinned to CPUs?
    pub fn pinned(&self) -> bool {
        self.pin
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Striping work threshold in bytes (below it, calls run inline).
    pub fn par_work(&self) -> usize {
        self.par_work
    }

    /// Has the worker pool been started (first parallel call ran)?
    pub fn pool_started(&self) -> bool {
        self.pool.get().is_some()
    }

    /// One-line description for logs and `unilrc engine`.
    pub fn describe(&self) -> String {
        format!(
            "kernel={} threads={} lane={}KiB par_work={}KiB chunk={} nt={} pin={} merge={} \
             pool={}",
            self.kernel,
            self.threads,
            self.lane / 1024,
            self.par_work / 1024,
            match self.chunk {
                Some(c) => format!("{}KiB", c.div_ceil(1024)),
                None => "adaptive".to_string(),
            },
            if self.nt == usize::MAX {
                "off".to_string()
            } else {
                format!("{}KiB", self.nt / 1024)
            },
            if self.pin { "on" } else { "off" },
            if self.merge { "on" } else { "off" },
            if self.threads <= 1 {
                "off"
            } else if self.pool_started() {
                "running"
            } else {
                "lazy"
            }
        )
    }

    /// Batch task granularity in input bytes per pool task, for a batch
    /// touching `work` total input bytes: the explicit `--gf-chunk-kb`
    /// override if set, otherwise `work / (workers × ~3)` rounded up to
    /// whole lanes — so a huge multi-stripe event lands ~2–4 tasks on each
    /// worker instead of thousands of lane-sized ones, while small events
    /// floor at one lane and keep their parallelism.
    pub fn batch_chunk(&self, work: usize) -> usize {
        if let Some(c) = self.chunk {
            return c;
        }
        let tasks = self.threads.max(1) * BATCH_TASKS_PER_WORKER;
        work.div_ceil(tasks).div_ceil(self.lane).max(1) * self.lane
    }

    /// Output bytes each pool task of a batched op produces, for an op
    /// reading `sources` input slices within a batch of `work` total input
    /// bytes: the batch granularity divided across the op's inputs, in
    /// whole lanes, floored at one lane. (Chunking is per-op: a batch of
    /// more stripes than workers still enqueues at least one task per
    /// stripe.)
    pub fn batch_step(&self, work: usize, sources: usize) -> usize {
        (self.batch_chunk(work) / (self.lane * sources.max(1))).max(1) * self.lane
    }

    /// The persistent pool, started on first use; `None` when the engine is
    /// single-threaded.
    fn pool(&self) -> Option<&WorkPool> {
        if self.threads <= 1 {
            return None;
        }
        Some(
            self.pool
                .get_or_init(|| Arc::new(WorkPool::with_pinning(self.threads, self.pin)))
                .as_ref(),
        )
    }

    /// Whether an op writing `span` total output bytes should use the
    /// streaming (non-temporal) store kernels: only on x86_64 vector tiers
    /// that have NT variants, and only past the threshold — outputs that
    /// fit in cache are re-read cheaply, and streaming them out would
    /// force the next reader to DRAM.
    fn nt_for(&self, span: usize) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            matches!(self.kernel, Kernel::Avx2 | Kernel::Avx512 | Kernel::Gfni) && span >= self.nt
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = span;
            false
        }
    }

    // ------------------------------------------------------------ slice ops

    /// `dst ^= c · src` on the selected tier.
    pub fn mul_acc(&self, c: u8, src: &[u8], dst: &mut [u8]) {
        assert_eq!(dst.len(), src.len(), "mul_acc length mismatch");
        // The SWAR tier derives its plane constants from `c` directly —
        // don't build lookup tables it would never read.
        if self.kernel == Kernel::Scalar {
            return slice::mul_acc_slice_scalar(c, src, dst);
        }
        match c {
            0 => {}
            1 => self.xor(dst, src),
            _ => self.mul_acc_kernel(&NibbleTables::new(c), src, dst),
        }
    }

    /// `dst ^= c · src` with the coefficient's tables precomputed (the
    /// cached-plan hot path: no per-call table build).
    pub fn mul_acc_t(&self, t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        assert_eq!(dst.len(), src.len(), "mul_acc_t length mismatch");
        match t.c {
            0 => {}
            1 => self.xor(dst, src),
            _ => self.mul_acc_kernel(t, src, dst),
        }
    }

    fn mul_acc_kernel(&self, t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        // SAFETY: `GfEngine::new`/`with_kernel` only store tiers that
        // `Kernel::available()` confirmed on this CPU.
        match self.kernel {
            Kernel::Scalar => slice::mul_acc_slice_scalar(t.c, src, dst),
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => unsafe { super::simd::x86_64::mul_acc_ssse3(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::mul_acc_avx2(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => unsafe { super::simd::x86_64::mul_acc_avx512(t, src, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Gfni => unsafe { super::simd::x86_64::mul_acc_gfni(t, src, dst) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::mul_acc_neon(t, src, dst) },
            _ => slice::mul_acc_slice_scalar(t.c, src, dst),
        }
    }

    /// Fused `dst ^= c1 · src1 ^ c2 · src2`: one load + one store of `dst`
    /// per two source slices (the SIMD tiers read both products before
    /// touching `dst`), versus two full read-modify-write passes with
    /// back-to-back [`Self::mul_acc_t`]. This is the inner step of
    /// [`Self::matmul_blocks_t`], where `dst` traffic dominates once the
    /// tables are cached.
    pub fn mul_acc2_t(
        &self,
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        assert_eq!(dst.len(), src1.len(), "mul_acc2_t src1 length mismatch");
        assert_eq!(dst.len(), src2.len(), "mul_acc2_t src2 length mismatch");
        // A zero coefficient degenerates to the single-source op (which
        // also keeps the c=1 XOR fast path for the surviving source).
        if t1.c == 0 {
            return self.mul_acc_t(t2, src2, dst);
        }
        if t2.c == 0 {
            return self.mul_acc_t(t1, src1, dst);
        }
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Ssse3 => unsafe {
                super::simd::x86_64::mul_acc2_ssse3(t1, src1, t2, src2, dst)
            },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::mul_acc2_avx2(t1, src1, t2, src2, dst) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => unsafe {
                super::simd::x86_64::mul_acc2_avx512(t1, src1, t2, src2, dst)
            },
            #[cfg(target_arch = "x86_64")]
            Kernel::Gfni => unsafe { super::simd::x86_64::mul_acc2_gfni(t1, src1, t2, src2, dst) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::mul_acc2_neon(t1, src1, t2, src2, dst) },
            _ => {
                slice::mul_acc_slice_scalar(t1.c, src1, dst);
                slice::mul_acc_slice_scalar(t2.c, src2, dst);
            }
        }
    }

    /// `dst ^= src` on the selected tier.
    pub fn xor(&self, dst: &mut [u8], src: &[u8]) {
        assert_eq!(dst.len(), src.len(), "xor length mismatch");
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::xor_avx2(dst, src) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 | Kernel::Gfni => unsafe { super::simd::x86_64::xor_avx512(dst, src) },
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => unsafe { super::simd::aarch64::xor_neon(dst, src) },
            _ => slice::xor_slice_scalar(dst, src),
        }
    }

    // --------------------------------------------- streaming-store kernels

    /// `dst = src` with streaming stores (callers checked [`Self::nt_for`];
    /// tiers without NT variants fall back to a plain copy).
    fn copy_nt(&self, dst: &mut [u8], src: &[u8]) {
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::copy_nt_avx2(dst, src) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 | Kernel::Gfni => unsafe {
                super::simd::x86_64::copy_nt_avx512(dst, src)
            },
            _ => dst.copy_from_slice(src),
        }
    }

    /// `dst = a ^ b` with streaming stores — `dst` is never read.
    fn xor_nt(&self, dst: &mut [u8], a: &[u8], b: &[u8]) {
        // SAFETY: kernel availability established at construction.
        match self.kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { super::simd::x86_64::xor_nt_avx2(dst, a, b) },
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 | Kernel::Gfni => unsafe {
                super::simd::x86_64::xor_nt_avx512(dst, a, b)
            },
            _ => {
                dst.copy_from_slice(a);
                self.xor(dst, b);
            }
        }
    }

    /// `dst = acc ^ c·src` with streaming stores — the fused final pass of
    /// an NT accumulation: `acc` is the cache-resident scratch, `dst` the
    /// big output written straight to memory exactly once.
    fn mul_into_nt(&self, t: &NibbleTables, src: &[u8], acc: &[u8], dst: &mut [u8]) {
        match t.c {
            0 => self.copy_nt(dst, acc),
            1 => self.xor_nt(dst, acc, src),
            // SAFETY: kernel availability established at construction.
            _ => match self.kernel {
                #[cfg(target_arch = "x86_64")]
                Kernel::Avx2 => unsafe {
                    super::simd::x86_64::mul_into_nt_avx2(t, src, acc, dst)
                },
                #[cfg(target_arch = "x86_64")]
                Kernel::Avx512 => unsafe {
                    super::simd::x86_64::mul_into_nt_avx512(t, src, acc, dst)
                },
                #[cfg(target_arch = "x86_64")]
                Kernel::Gfni => unsafe {
                    super::simd::x86_64::mul_into_nt_gfni(t, src, acc, dst)
                },
                _ => {
                    dst.copy_from_slice(acc);
                    self.mul_acc_t(t, src, dst);
                }
            },
        }
    }

    // -------------------------------------------------------- striped ops

    /// Worker count for a call touching `block`-byte rows and `work` total
    /// input bytes; 1 means run inline.
    fn workers_for(&self, block: usize, work: usize) -> usize {
        if self.threads <= 1 || work < self.par_work || block < 2 * self.lane {
            1
        } else {
            self.threads.min(block.div_ceil(self.lane))
        }
    }

    /// One lane of a fold: `c = srcs[0][o..] ^ srcs[1][o..] ^ …`. The NT
    /// variant never reads `c`: one- and two-source folds stream directly;
    /// longer folds accumulate all but the last source in a cache-resident
    /// pooled scratch, then fuse the last source into a single pure-store
    /// sweep of `c`.
    fn fold_lane(&self, c: &mut [u8], srcs: &[&[u8]], o: usize, nt: bool) {
        let w = c.len();
        if !nt {
            c.copy_from_slice(&srcs[0][o..o + w]);
            for s in &srcs[1..] {
                self.xor(c, &s[o..o + w]);
            }
            return;
        }
        let n = srcs.len();
        match n {
            1 => self.copy_nt(c, &srcs[0][o..o + w]),
            2 => self.xor_nt(c, &srcs[0][o..o + w], &srcs[1][o..o + w]),
            _ => {
                let mut scratch = super::pool::take_for_overwrite(w);
                scratch.copy_from_slice(&srcs[0][o..o + w]);
                for s in &srcs[1..n - 1] {
                    self.xor(&mut scratch, &s[o..o + w]);
                }
                self.xor_nt(c, &scratch, &srcs[n - 1][o..o + w]);
                super::pool::recycle(scratch);
            }
        }
    }

    /// Whole-block fold, one lane at a time so src+dst (or the NT scratch)
    /// stay cache-resident.
    fn fold_whole(&self, dst: &mut [u8], srcs: &[&[u8]], nt: bool) {
        let lane = self.lane;
        let mut off = 0usize;
        for c in dst.chunks_mut(lane) {
            let o = off;
            off += c.len();
            self.fold_lane(c, srcs, o, nt);
        }
    }

    /// `dst = srcs[0] ^ srcs[1] ^ …`, striped across the worker pool for
    /// large blocks (the UniLRC repair path). Outputs past the streaming
    /// threshold ([`Self::nt_for`]) are written with non-temporal stores.
    pub fn fold_blocks(&self, dst: &mut [u8], srcs: &[&[u8]]) {
        assert!(!srcs.is_empty(), "fold needs at least one source");
        for s in srcs {
            assert_eq!(s.len(), dst.len(), "fold length mismatch");
        }
        let block = dst.len();
        let nt = self.nt_for(block);
        let workers = self.workers_for(block, block * srcs.len());
        let pool = if workers > 1 { self.pool() } else { None };
        let Some(pool) = pool else {
            self.fold_whole(dst, srcs, nt);
            return;
        };
        let lane = self.lane;
        // Group whole lanes into one task per worker; within a task, each
        // lane is folded before the next so src+dst stay cache-resident.
        let per = block.div_ceil(lane).div_ceil(workers).max(1) * lane;
        pool.scope(|scope| {
            let mut off = 0usize;
            for chunk in dst.chunks_mut(per) {
                let base = off;
                off += chunk.len();
                scope.submit(move || {
                    for (l, c) in chunk.chunks_mut(lane).enumerate() {
                        self.fold_lane(c, srcs, base + l * lane, nt);
                    }
                });
            }
        });
    }

    /// Matrix-style coding primitive: `outs[i] = ⊕_j coeff[i][j] · srcs[j]`,
    /// striped across the worker pool. Each task owns a disjoint byte range
    /// of every output row and walks it source-major, so one cache-resident
    /// lane of each source is scattered into all rows before moving on.
    /// Outputs may be `Vec<u8>` or pooled buffers ([`super::pool::PooledBuf`]).
    pub fn matmul_blocks<B: AsMut<[u8]> + Send>(
        &self,
        coeff: &[&[u8]],
        srcs: &[&[u8]],
        outs: &mut [B],
    ) {
        let tables = NibbleTables::for_rows(coeff.iter().copied());
        self.matmul_blocks_t(&tables, srcs, outs);
    }

    /// [`Self::matmul_blocks`] with per-coefficient tables prebuilt — the
    /// entry point for cached decode plans.
    pub fn matmul_blocks_t<B: AsMut<[u8]> + Send>(
        &self,
        tables: &[Vec<NibbleTables>],
        srcs: &[&[u8]],
        outs: &mut [B],
    ) {
        assert_eq!(tables.len(), outs.len(), "row count mismatch");
        let block = srcs.first().map_or(0, |s| s.len());
        for (row, out) in tables.iter().zip(outs.iter_mut()) {
            assert_eq!(row.len(), srcs.len(), "column count mismatch");
            assert_eq!(out.as_mut().len(), block, "output block size mismatch");
        }
        let nt = self.nt_for(block * outs.len());
        let workers = self.workers_for(block, block * srcs.len() * outs.len().max(1));
        let pool = if workers > 1 && !outs.is_empty() { self.pool() } else { None };
        let Some(pool) = pool else {
            self.matmul_whole(tables, srcs, outs, nt);
            return;
        };
        let lane = self.lane;
        let nlanes = block.div_ceil(lane);
        // Transpose row-major chunking into lane-major work items: lane l
        // holds the l-th chunk of every output row (disjoint &mut borrows).
        let mut row_chunks: Vec<_> =
            outs.iter_mut().map(|o| o.as_mut().chunks_mut(lane)).collect();
        let mut lanes: Vec<(usize, Vec<&mut [u8]>)> = Vec::with_capacity(nlanes);
        for l in 0..nlanes {
            let chunk: Vec<&mut [u8]> =
                row_chunks.iter_mut().map(|it| it.next().expect("lane chunk")).collect();
            lanes.push((l * lane, chunk));
        }
        let per = nlanes.div_ceil(workers);
        pool.scope(|scope| {
            while !lanes.is_empty() {
                let mut group: Vec<_> = lanes.drain(..per.min(lanes.len())).collect();
                scope.submit(move || {
                    for (off, louts) in group.iter_mut() {
                        self.matmul_lane(tables, srcs, *off, louts, nt);
                    }
                });
            }
        });
    }

    /// Whole-block matmul run inline, one lane at a time so each output
    /// window (or its NT scratch) stays cache-resident across the fused
    /// source pairs.
    fn matmul_whole<B: AsMut<[u8]>>(
        &self,
        tables: &[Vec<NibbleTables>],
        srcs: &[&[u8]],
        outs: &mut [B],
        nt: bool,
    ) {
        let lane = self.lane;
        let mut rows: Vec<&mut [u8]> = outs.iter_mut().map(|o| o.as_mut()).collect();
        let block = rows.first().map_or(0, |o| o.len());
        let nsub = block.div_ceil(lane);
        let mut subs: Vec<_> = rows.iter_mut().map(|o| o.chunks_mut(lane)).collect();
        for s in 0..nsub {
            let mut lane_outs: Vec<&mut [u8]> =
                subs.iter_mut().map(|it| it.next().expect("lane chunk")).collect();
            self.matmul_lane(tables, srcs, s * lane, &mut lane_outs, nt);
        }
    }

    /// One lane of the matmul: outputs are the `[off..off+w)` sub-slices of
    /// the full rows; sources are indexed with the same offset. Sources are
    /// consumed in fused pairs ([`Self::mul_acc2_t`]) so each output lane
    /// is loaded/stored once per *two* sources.
    fn matmul_lane(
        &self,
        tables: &[Vec<NibbleTables>],
        srcs: &[&[u8]],
        off: usize,
        louts: &mut [&mut [u8]],
        nt: bool,
    ) {
        if nt {
            return self.matmul_lane_nt(tables, srcs, off, louts);
        }
        for out in louts.iter_mut() {
            out.fill(0);
        }
        let mut j = 0;
        while j + 1 < srcs.len() {
            for (row, out) in tables.iter().zip(louts.iter_mut()) {
                let w = out.len();
                self.mul_acc2_t(
                    &row[j],
                    &srcs[j][off..off + w],
                    &row[j + 1],
                    &srcs[j + 1][off..off + w],
                    out,
                );
            }
            j += 2;
        }
        if j < srcs.len() {
            for (row, out) in tables.iter().zip(louts.iter_mut()) {
                let w = out.len();
                self.mul_acc_t(&row[j], &srcs[j][off..off + w], out);
            }
        }
    }

    /// [`Self::matmul_lane`] with streaming stores: each output lane is
    /// accumulated in one cache-resident pooled scratch (all sources but
    /// the last), then the last source is fused into a single pure-store
    /// sweep of the output ([`Self::mul_into_nt`]) — the big output is
    /// written to DRAM exactly once and never read.
    fn matmul_lane_nt(
        &self,
        tables: &[Vec<NibbleTables>],
        srcs: &[&[u8]],
        off: usize,
        louts: &mut [&mut [u8]],
    ) {
        let w = louts.first().map_or(0, |o| o.len());
        if srcs.is_empty() {
            // No sources: every output row is all-zero; stream zeros out.
            let scratch = super::pool::take_zeroed(w);
            for out in louts.iter_mut() {
                self.copy_nt(out, &scratch);
            }
            super::pool::recycle(scratch);
            return;
        }
        let last = srcs.len() - 1;
        let mut scratch = super::pool::take_for_overwrite(w);
        for (row, out) in tables.iter().zip(louts.iter_mut()) {
            scratch.fill(0);
            let mut j = 0;
            while j + 1 < last {
                self.mul_acc2_t(
                    &row[j],
                    &srcs[j][off..off + w],
                    &row[j + 1],
                    &srcs[j + 1][off..off + w],
                    &mut scratch,
                );
                j += 2;
            }
            if j < last {
                self.mul_acc_t(&row[j], &srcs[j][off..off + w], &mut scratch);
            }
            self.mul_into_nt(&row[last], &srcs[last][off..off + w], &scratch, out);
        }
        super::pool::recycle(scratch);
    }

    // -------------------------------------------------------- batched ops

    /// Apply one coefficient-table matrix to many stripes in a single
    /// batched wave: `result[s][i] = ⊕_j tables[i][j] · stripes[s][j]`.
    /// This is the shared engine for `Code::encode_stripes`,
    /// `DecodePlan::execute_batch`, and `CachedPlan::execute_batch`.
    /// Output buffers are 64-byte-aligned pooled blocks (callers should
    /// [`recycle`](super::pool::recycle) them); every byte is overwritten.
    pub fn matmul_stripes_t(
        &self,
        tables: &[Vec<NibbleTables>],
        stripes: &[Vec<&[u8]>],
    ) -> Vec<Vec<super::pool::PooledBuf>> {
        let mut all: Vec<Vec<super::pool::PooledBuf>> = stripes
            .iter()
            .map(|sources| {
                let len = sources.first().map_or(0, |s| s.len());
                (0..tables.len()).map(|_| super::pool::take_for_overwrite(len)).collect()
            })
            .collect();
        let work: usize =
            stripes.iter().map(|s| s.iter().map(|b| b.len()).sum::<usize>()).sum::<usize>();
        self.batch(work, |b| {
            for (sources, outs) in stripes.iter().zip(all.iter_mut()) {
                b.matmul_t(tables, sources.clone(), outs);
            }
        });
        all
    }

    /// Run a *batch* of coding operations as one pool submission wave:
    /// `f` receives a [`CodingBatch`] and enqueues any number of folds /
    /// matmuls (typically one per stripe of a recovery or read event); all
    /// of them have completed when `batch` returns. `work` is the total
    /// input bytes the batch will touch — below the engine's striping
    /// threshold (or on a single-threaded engine) the ops run inline in
    /// submission order instead of through the pool.
    ///
    /// This is how multi-stripe events beat the per-call striping gate on
    /// small blocks: a 64 KiB block is too small to stripe by itself, but
    /// 40 stripes × 64 KiB submitted together keep every worker busy.
    pub fn batch<'env, R, F>(&'env self, work: usize, f: F) -> R
    where
        F: for<'scope> FnOnce(&mut CodingBatch<'scope, 'env>) -> R,
    {
        let chunk = self.batch_chunk(work);
        // Streaming is decided batch-wide: the batch's aggregate output is
        // what blows the cache, even when each op's own span is small.
        let nt = self.nt_for(work);
        let pool = if self.threads > 1 && work >= self.par_work { self.pool() } else { None };
        match pool {
            Some(pool) => pool.scope(|scope| {
                let mut b = CodingBatch {
                    engine: self,
                    scope: Some(scope),
                    chunk,
                    nt,
                    pending: Vec::new(),
                    pending_work: 0,
                };
                let r = f(&mut b);
                b.flush();
                r
            }),
            None => {
                let mut b = CodingBatch {
                    engine: self,
                    scope: None,
                    chunk,
                    nt,
                    pending: Vec::new(),
                    pending_work: 0,
                };
                let r = f(&mut b);
                b.flush();
                r
            }
        }
    }
}

/// A batch of coding operations submitted to the engine's worker pool in
/// one wave (see [`GfEngine::batch`]). Ops enqueued here do **not** run
/// eagerly — they complete by the time `batch` returns. Large ops are split
/// into lane-sized tasks so the pool load-balances across stripes; ops
/// *smaller* than one task's granularity are **merged** — queued up and run
/// as one shared pool task — so a burst of thousands of tiny stripes costs
/// far fewer queue round-trips than one task per stripe (disable with
/// `UNILRC_GF_MERGE=0`).
pub struct CodingBatch<'scope, 'env: 'scope> {
    engine: &'env GfEngine,
    /// `None` ⇒ run ops inline (single-threaded engine or tiny batch).
    scope: Option<&'scope BatchScope<'scope, 'env>>,
    /// Input-work granularity per pool task for this batch, fixed when the
    /// batch opened (adaptive or the `--gf-chunk-kb` override).
    chunk: usize,
    /// Batch-wide streaming-store decision (total output ≫ threshold).
    nt: bool,
    /// Small ops awaiting fusion into one shared pool task.
    pending: Vec<Box<dyn FnOnce(&GfEngine) + Send + 'env>>,
    /// Input bytes accumulated in `pending`.
    pending_work: usize,
}

impl<'scope, 'env> CodingBatch<'scope, 'env> {
    /// Output bytes per task for an op reading `sources` slices: the batch
    /// granularity spread across the op's inputs, whole lanes, floored at
    /// one lane (mirrors [`GfEngine::batch_step`]).
    fn step(&self, sources: usize) -> usize {
        (self.chunk / (self.engine.lane * sources.max(1))).max(1) * self.engine.lane
    }

    /// Enqueue an arbitrary engine task (advanced callers).
    pub fn submit<F>(&mut self, f: F)
    where
        F: FnOnce(&GfEngine) + Send + 'env,
    {
        let engine = self.engine;
        match self.scope {
            None => f(engine),
            Some(scope) => scope.submit(move || f(engine)),
        }
    }

    /// Queue a sub-chunk op for merging; ships the group once it has
    /// accumulated one task's worth of input work.
    fn push_merged<F>(&mut self, work: usize, f: F)
    where
        F: FnOnce(&GfEngine) + Send + 'env,
    {
        self.pending.push(Box::new(f));
        self.pending_work += work;
        if self.pending_work >= self.chunk {
            self.flush();
        }
    }

    /// Submit any merged small ops as one pool task (no-op when empty).
    /// [`GfEngine::batch`] calls this after the enqueue closure returns, so
    /// callers never need to.
    pub fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let group = std::mem::take(&mut self.pending);
        self.pending_work = 0;
        let engine = self.engine;
        match self.scope {
            None => {
                for f in group {
                    f(engine);
                }
            }
            Some(scope) => scope.submit(move || {
                for f in group {
                    f(engine);
                }
            }),
        }
    }

    /// Enqueue `dst = srcs[0] ^ srcs[1] ^ …` (XOR-local repair of one
    /// stripe within a batched event).
    pub fn fold(&mut self, dst: &'env mut [u8], srcs: Vec<&'env [u8]>) {
        assert!(!srcs.is_empty(), "fold needs at least one source");
        for s in &srcs {
            assert_eq!(s.len(), dst.len(), "fold length mismatch");
        }
        let engine = self.engine;
        let nt = self.nt;
        if self.scope.is_none() {
            engine.fold_whole(dst, &srcs, nt);
            return;
        }
        // An op below one task's granularity would occupy a whole queue
        // round-trip by itself — merge it with its neighbours instead.
        let work = dst.len() * srcs.len();
        if engine.merge && work < self.chunk {
            self.push_merged(work, move |e| e.fold_whole(dst, &srcs, nt));
            return;
        }
        let scope = self.scope.expect("checked above");
        let step = self.step(srcs.len());
        let lane = engine.lane;
        // One shared allocation for the source list; tasks clone the Arc.
        let srcs = Arc::new(srcs);
        let mut off = 0usize;
        for c in dst.chunks_mut(step) {
            let base = off;
            off += c.len();
            let srcs = Arc::clone(&srcs);
            // Within a task, fold one lane at a time so src+dst stay
            // cache-resident however large the task's span is.
            scope.submit(move || {
                for (l, sub) in c.chunks_mut(lane).enumerate() {
                    engine.fold_lane(sub, &srcs, base + l * lane, nt);
                }
            });
        }
    }

    /// Enqueue `outs[i] = ⊕_j tables[i][j] · srcs[j]` (one stripe's encode
    /// or decode within a batched event). `tables` must outlive the batch —
    /// build them once and share them across every stripe of the event.
    /// Outputs may be `Vec<u8>` or pooled buffers.
    pub fn matmul_t<B: AsMut<[u8]> + Send>(
        &mut self,
        tables: &'env [Vec<NibbleTables>],
        srcs: Vec<&'env [u8]>,
        outs: &'env mut [B],
    ) {
        assert_eq!(tables.len(), outs.len(), "row count mismatch");
        let block = srcs.first().map_or(0, |s| s.len());
        for (row, out) in tables.iter().zip(outs.iter_mut()) {
            assert_eq!(row.len(), srcs.len(), "column count mismatch");
            assert_eq!(out.as_mut().len(), block, "output block size mismatch");
        }
        let engine = self.engine;
        let nt = self.nt;
        if self.scope.is_none() {
            engine.matmul_whole(tables, &srcs, outs, nt);
            return;
        }
        if outs.is_empty() {
            return;
        }
        // Merge sub-chunk stripes into shared tasks (see `fold`).
        let work = block * srcs.len();
        if engine.merge && work < self.chunk {
            self.push_merged(work, move |e| e.matmul_whole(tables, &srcs, outs, nt));
            return;
        }
        let scope = self.scope.expect("checked above");
        let step = self.step(srcs.len());
        let lane = engine.lane;
        let ntasks = block.div_ceil(step);
        // One shared allocation for the source list; tasks clone the Arc.
        let srcs = Arc::new(srcs);
        let mut row_chunks: Vec<_> =
            outs.iter_mut().map(|o| o.as_mut().chunks_mut(step)).collect();
        for t in 0..ntasks {
            let mut louts: Vec<&mut [u8]> =
                row_chunks.iter_mut().map(|it| it.next().expect("task chunk")).collect();
            let srcs = Arc::clone(&srcs);
            let off = t * step;
            // Within a task, run the matmul one lane at a time so each
            // output window stays cache-resident across the fused source
            // pairs, however large the task's span is.
            scope.submit(move || {
                let nsub = louts.first().map_or(0, |o| o.len().div_ceil(lane));
                let mut subs: Vec<_> = louts.iter_mut().map(|o| o.chunks_mut(lane)).collect();
                for s in 0..nsub {
                    let mut lane_outs: Vec<&mut [u8]> =
                        subs.iter_mut().map(|it| it.next().expect("lane chunk")).collect();
                    engine.matmul_lane(tables, &srcs, off + s * lane, &mut lane_outs, nt);
                }
            });
        }
    }
}

static GLOBAL: OnceLock<GfEngine> = OnceLock::new();

/// The process-wide engine. First use freezes it: initialized from the
/// environment ([`GfEngine::from_env`]) unless [`install`] ran earlier.
pub fn engine() -> &'static GfEngine {
    GLOBAL.get_or_init(GfEngine::from_env)
}

/// Install a specific engine as the process-wide one (CLI `--gf-kernel` /
/// config `[experiment] gf_kernel`). Returns `false` if the engine was
/// already initialized — the caller should warn that the override is late.
pub fn install(e: GfEngine) -> bool {
    GLOBAL.set(e).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::tables::gf_mul;
    use crate::prng::Prng;

    fn available_kernels() -> Vec<Kernel> {
        Kernel::all().into_iter().filter(|k| k.available()).collect()
    }

    #[test]
    fn detect_is_available() {
        assert!(Kernel::detect().available());
    }

    #[test]
    fn parse_roundtrip() {
        for k in Kernel::all() {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert!(Kernel::parse("auto").is_some());
        assert_eq!(Kernel::parse("nope"), None);
    }

    #[test]
    fn unavailable_kernel_falls_back_to_scalar() {
        // At most one of AVX2/NEON exists on any one machine, so whichever
        // is foreign must clamp to scalar rather than crash later.
        for k in Kernel::all() {
            let e = GfEngine::new(k);
            assert!(e.kernel().available());
        }
    }

    #[test]
    fn every_tier_matches_reference_mul_acc() {
        let mut p = Prng::new(17);
        let src = p.bytes(1000);
        let init = p.bytes(1000);
        for k in available_kernels() {
            let e = GfEngine::new(k);
            for c in [0u8, 1, 2, 0x53, 0xFF] {
                let mut dst = init.clone();
                e.mul_acc(c, &src, &mut dst);
                let expect: Vec<u8> =
                    init.iter().zip(&src).map(|(&d, &s)| d ^ gf_mul(c, s)).collect();
                assert_eq!(dst, expect, "kernel={k} c={c}");
            }
        }
    }

    #[test]
    fn mul_acc2_matches_two_single_ops() {
        let mut p = Prng::new(23);
        // straddle the vector widths and exercise the scalar tail
        for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 64, 1000] {
            let s1 = p.bytes(len);
            let s2 = p.bytes(len);
            let init = p.bytes(len);
            for k in available_kernels() {
                let e = GfEngine::new(k);
                for (c1, c2) in [(0u8, 0u8), (0, 7), (1, 1), (1, 0x53), (2, 3), (0x53, 0xFF)] {
                    let (t1, t2) = (NibbleTables::new(c1), NibbleTables::new(c2));
                    let mut fused = init.clone();
                    e.mul_acc2_t(&t1, &s1, &t2, &s2, &mut fused);
                    let mut seq = init.clone();
                    e.mul_acc_t(&t1, &s1, &mut seq);
                    e.mul_acc_t(&t2, &s2, &mut seq);
                    assert_eq!(fused, seq, "kernel={k} c1={c1} c2={c2} len={len}");
                }
            }
        }
    }

    #[test]
    fn striped_matmul_matches_serial() {
        let mut p = Prng::new(18);
        let block = 10_000; // not a lane multiple: exercises the short tail lane
        let srcs: Vec<Vec<u8>> = (0..5).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let rows: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(5)).collect();
        let rrefs: Vec<&[u8]> = rows.iter().map(|v| v.as_slice()).collect();

        let serial = GfEngine::scalar();
        let mut expect = vec![vec![0u8; block]; 3];
        serial.matmul_blocks(&rrefs, &refs, &mut expect);

        for k in available_kernels() {
            let par = GfEngine::new(k).with_threads(4).with_lane(1024).with_par_work(0);
            let mut got = vec![vec![1u8; block]; 3]; // nonzero: checks overwrite
            par.matmul_blocks(&rrefs, &refs, &mut got);
            assert_eq!(got, expect, "kernel={k}");
        }
    }

    #[test]
    fn striped_fold_matches_serial() {
        let mut p = Prng::new(19);
        let block = 7777;
        let srcs: Vec<Vec<u8>> = (0..6).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut expect = vec![0u8; block];
        GfEngine::scalar().fold_blocks(&mut expect, &refs);
        for k in available_kernels() {
            let par = GfEngine::new(k).with_threads(3).with_lane(512).with_par_work(0);
            let mut got = vec![9u8; block];
            par.fold_blocks(&mut got, &refs);
            assert_eq!(got, expect, "kernel={k}");
        }
    }

    #[test]
    fn empty_matmul_ok() {
        let mut outs: Vec<Vec<u8>> = vec![];
        GfEngine::auto().matmul_blocks(&[], &[], &mut outs);
        assert!(outs.is_empty());
    }

    #[test]
    fn pool_is_lazy_and_reused_across_calls() {
        let mut p = Prng::new(20);
        let e = GfEngine::new(Kernel::detect()).with_threads(2).with_lane(256).with_par_work(0);
        assert!(!e.pool_started(), "pool must not start before a parallel call");
        let srcs: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(4096)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u8; 4096];
        e.fold_blocks(&mut out, &refs);
        assert!(e.pool_started());
        let clone = e.clone();
        assert!(clone.pool_started(), "clones share the started pool");
    }

    #[test]
    fn adaptive_chunk_scales_with_work_and_floors_at_lane() {
        let e = GfEngine::new(Kernel::Scalar).with_threads(2).with_lane(4096);
        // tiny or empty batches floor at one lane
        assert_eq!(e.batch_chunk(0), 4096);
        assert_eq!(e.batch_chunk(100), 4096);
        // large batches land ~2–4 tasks per worker, in whole lanes
        let work = 60 * 4096 * 6;
        let chunk = e.batch_chunk(work);
        assert_eq!(chunk % 4096, 0);
        let tasks = work.div_ceil(chunk);
        assert!((2..=8).contains(&tasks), "tasks={tasks} for 2 workers");
        // explicit override wins at any work size; 0 restores adaptive
        let o = e.clone().with_chunk(12345);
        assert_eq!(o.batch_chunk(1 << 30), 12345);
        assert_eq!(o.with_chunk(0).batch_chunk(0), 4096);
    }

    #[test]
    fn batch_step_spreads_chunk_across_sources_with_lane_floor() {
        let e = GfEngine::new(Kernel::Scalar).with_threads(2).with_lane(1024).with_chunk(64);
        // absurdly small explicit chunk: per-task output is still one lane
        assert_eq!(e.batch_step(1 << 20, 8), 1024);
        let e = e.with_chunk(1 << 20);
        let step = e.batch_step(1 << 20, 4);
        assert_eq!(step % 1024, 0);
        assert_eq!(step, (1 << 20) / (1024 * 4) * 1024);
    }

    #[test]
    fn batch_matches_sequential_ops() {
        let mut p = Prng::new(21);
        let block = 3000;
        let stripes = 5;
        let all_srcs: Vec<Vec<Vec<u8>>> =
            (0..stripes).map(|_| (0..4).map(|_| p.bytes(block)).collect()).collect();
        let coeff: Vec<Vec<u8>> = (0..2).map(|_| p.bytes(4)).collect();
        let tables: Vec<Vec<NibbleTables>> = coeff
            .iter()
            .map(|row| row.iter().map(|&c| NibbleTables::new(c)).collect())
            .collect();

        let serial = GfEngine::scalar();
        let crefs: Vec<&[u8]> = coeff.iter().map(|v| v.as_slice()).collect();
        let mut expect: Vec<Vec<Vec<u8>>> = Vec::new();
        for srcs in &all_srcs {
            let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let mut outs = vec![vec![0u8; block]; 2];
            serial.matmul_blocks(&crefs, &refs, &mut outs);
            expect.push(outs);
        }

        for threads in [1usize, 2, 8] {
            let e = GfEngine::new(Kernel::detect())
                .with_threads(threads)
                .with_lane(512)
                .with_par_work(0);
            let mut got: Vec<Vec<Vec<u8>>> = vec![vec![vec![7u8; block]; 2]; stripes];
            e.batch(stripes * 4 * block, |b| {
                for (srcs, outs) in all_srcs.iter().zip(got.iter_mut()) {
                    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                    b.matmul_t(&tables, refs, outs);
                }
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn nt_on_and_off_produce_identical_results() {
        let mut p = Prng::new(31);
        let block = 50_000; // not a lane multiple: exercises the short tail lane
        let srcs: Vec<Vec<u8>> = (0..5).map(|_| p.bytes(block)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let rows: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(5)).collect();
        let rrefs: Vec<&[u8]> = rows.iter().map(|v| v.as_slice()).collect();
        for k in available_kernels() {
            for threads in [1usize, 4] {
                let base =
                    GfEngine::new(k).with_threads(threads).with_lane(1024).with_par_work(0);
                let off = base.clone().with_nt(usize::MAX);
                let on = base.with_nt(0);
                let mut a = vec![vec![0u8; block]; 3];
                let mut b = vec![vec![1u8; block]; 3];
                off.matmul_blocks(&rrefs, &refs, &mut a);
                on.matmul_blocks(&rrefs, &refs, &mut b);
                assert_eq!(a, b, "matmul kernel={k} threads={threads}");
                for n in [1usize, 2, 3, 5] {
                    let mut fa = vec![0u8; block];
                    let mut fb = vec![9u8; block];
                    off.fold_blocks(&mut fa, &refs[..n]);
                    on.fold_blocks(&mut fb, &refs[..n]);
                    assert_eq!(fa, fb, "fold kernel={k} threads={threads} n={n}");
                }
            }
        }
    }

    #[test]
    fn merged_batch_matches_unmerged() {
        let mut p = Prng::new(32);
        let block = 1500; // far below the chunk: every stripe takes the merge path
        let stripes = 12;
        let all_srcs: Vec<Vec<Vec<u8>>> =
            (0..stripes).map(|_| (0..4).map(|_| p.bytes(block)).collect()).collect();
        let coeff: Vec<Vec<u8>> = (0..2).map(|_| p.bytes(4)).collect();
        let tables: Vec<Vec<NibbleTables>> = coeff
            .iter()
            .map(|row| row.iter().map(|&c| NibbleTables::new(c)).collect())
            .collect();
        let run = |e: GfEngine| -> Vec<Vec<Vec<u8>>> {
            let mut got: Vec<Vec<Vec<u8>>> = vec![vec![vec![7u8; block]; 2]; stripes];
            e.batch(stripes * 4 * block, |b| {
                for (srcs, outs) in all_srcs.iter().zip(got.iter_mut()) {
                    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                    b.matmul_t(&tables, refs, outs);
                }
            });
            got
        };
        let base =
            GfEngine::new(Kernel::detect()).with_threads(4).with_lane(512).with_par_work(0);
        let merged = run(base.clone().with_merge(true));
        let unmerged = run(base.with_merge(false));
        assert_eq!(merged, unmerged);
    }

    #[test]
    fn batch_fold_matches_sequential() {
        let mut p = Prng::new(22);
        let block = 2049;
        let stripes = 4;
        let all_srcs: Vec<Vec<Vec<u8>>> =
            (0..stripes).map(|_| (0..5).map(|_| p.bytes(block)).collect()).collect();
        let mut expect: Vec<Vec<u8>> = Vec::new();
        for srcs in &all_srcs {
            let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0u8; block];
            GfEngine::scalar().fold_blocks(&mut out, &refs);
            expect.push(out);
        }
        for threads in [1usize, 2, 8] {
            let e = GfEngine::new(Kernel::detect())
                .with_threads(threads)
                .with_lane(512)
                .with_par_work(0);
            let mut got: Vec<Vec<u8>> = vec![vec![3u8; block]; stripes];
            e.batch(stripes * 5 * block, |b| {
                for (srcs, out) in all_srcs.iter().zip(got.iter_mut()) {
                    let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
                    b.fold(out, refs);
                }
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }
}
