//! Dense matrices over GF(2^8).
//!
//! Used for generator-matrix construction (§3.2), distance verification
//! (Theorem 3.2 rank arguments) and multi-failure decoding (parity-check
//! solves). These matrices are tiny (≤ a few hundred rows), so clarity wins
//! over blocking; the wide per-byte work lives in [`super::slice`].

use super::tables::{gf_div, gf_inv, gf_mul, gf_pow};
use std::fmt;

/// Row-major dense matrix over GF(2^8).
#[derive(Clone, PartialEq, Eq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<u8>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0; rows * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zero(n, n);
        for i in 0..n {
            m.set(i, i, 1);
        }
        m
    }

    /// Build from nested rows (panics on ragged input).
    pub fn from_rows(rows: &[Vec<u8>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Vandermonde matrix `V[i][j] = points[j]^(start + i)` with `rows` rows.
    ///
    /// With `start = 0` this is the classical Vandermonde; the UniLRC
    /// construction uses `start = 1` (rows g_j^1 .. g_j^{αz}, §3.2 Step 1).
    pub fn vandermonde(rows: usize, points: &[u8], start: usize) -> Self {
        let mut m = Matrix::zero(rows, points.len());
        for i in 0..rows {
            for (j, &p) in points.iter().enumerate() {
                m.set(i, j, gf_pow(p, start + i));
            }
        }
        m
    }

    /// Cauchy matrix `C[i][j] = 1 / (x_i + y_j)`; all `x_i`, `y_j` must be
    /// pairwise distinct across both sets (checked).
    pub fn cauchy(xs: &[u8], ys: &[u8]) -> Self {
        let mut m = Matrix::zero(xs.len(), ys.len());
        for (i, &x) in xs.iter().enumerate() {
            for (j, &y) in ys.iter().enumerate() {
                assert!(x != y, "cauchy: x and y sets intersect");
                m.set(i, j, gf_inv(x ^ y));
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> u8 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: u8) {
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [u8] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Matrix product over GF(2^8).
    pub fn mul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "dimension mismatch");
        let mut out = Matrix::zero(self.rows, other.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a = self.get(i, l);
                if a == 0 {
                    continue;
                }
                for j in 0..other.cols {
                    let v = out.get(i, j) ^ gf_mul(a, other.get(l, j));
                    out.set(i, j, v);
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn mul_vec(&self, v: &[u8]) -> Vec<u8> {
        assert_eq!(self.cols, v.len(), "dimension mismatch");
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(v)
                    .fold(0u8, |acc, (&a, &x)| acc ^ gf_mul(a, x))
            })
            .collect()
    }

    /// Vertical stack `[self; other]`.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "column mismatch");
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Horizontal stack `[self | other]`.
    pub fn hstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "row mismatch");
        let mut out = Matrix::zero(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Select a subset of columns (in the given order).
    pub fn select_cols(&self, cols: &[usize]) -> Matrix {
        let mut out = Matrix::zero(self.rows, cols.len());
        for i in 0..self.rows {
            for (jj, &j) in cols.iter().enumerate() {
                out.set(i, jj, self.get(i, j));
            }
        }
        out
    }

    /// Select a subset of rows (in the given order).
    pub fn select_rows(&self, rows: &[usize]) -> Matrix {
        let mut out = Matrix::zero(rows.len(), self.cols);
        for (ii, &i) in rows.iter().enumerate() {
            out.row_mut(ii).copy_from_slice(self.row(i));
        }
        out
    }

    /// Rank via Gaussian elimination (on a copy).
    pub fn rank(&self) -> usize {
        let mut m = self.clone();
        let mut rank = 0;
        for col in 0..m.cols {
            if rank == m.rows {
                break;
            }
            // find pivot
            let Some(p) = (rank..m.rows).find(|&r| m.get(r, col) != 0) else {
                continue;
            };
            m.data.swap_chunks(rank, p, m.cols);
            let inv = gf_inv(m.get(rank, col));
            for j in col..m.cols {
                let v = gf_mul(m.get(rank, j), inv);
                m.set(rank, j, v);
            }
            for r in 0..m.rows {
                if r != rank {
                    let f = m.get(r, col);
                    if f != 0 {
                        for j in col..m.cols {
                            let v = m.get(r, j) ^ gf_mul(f, m.get(rank, j));
                            m.set(r, j, v);
                        }
                    }
                }
            }
            rank += 1;
        }
        rank
    }

    /// Inverse via Gauss–Jordan. Returns `None` if singular.
    pub fn invert(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "inverse of non-square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            let p = (col..n).find(|&r| a.get(r, col) != 0)?;
            a.data.swap_chunks(col, p, n);
            inv.data.swap_chunks(col, p, n);
            let piv = gf_inv(a.get(col, col));
            for j in 0..n {
                a.set(col, j, gf_mul(a.get(col, j), piv));
                inv.set(col, j, gf_mul(inv.get(col, j), piv));
            }
            for r in 0..n {
                if r != col {
                    let f = a.get(r, col);
                    if f != 0 {
                        for j in 0..n {
                            let va = a.get(r, j) ^ gf_mul(f, a.get(col, j));
                            a.set(r, j, va);
                            let vi = inv.get(r, j) ^ gf_mul(f, inv.get(col, j));
                            inv.set(r, j, vi);
                        }
                    }
                }
            }
        }
        Some(inv)
    }

    /// Solve `A x = b` for square invertible `A` (convenience for small
    /// decode systems). Returns `None` if singular.
    pub fn solve(&self, b: &[u8]) -> Option<Vec<u8>> {
        assert_eq!(self.rows, b.len());
        Some(self.invert()?.mul_vec(b))
    }

    /// True if every entry of row `r` is 0 or 1 — the XOR-locality predicate
    /// for a parity row (§2.3.3).
    pub fn row_is_xor_only(&self, r: usize) -> bool {
        self.row(r).iter().all(|&c| c <= 1)
    }
}

/// Swap two equal-length row chunks inside one flat buffer.
trait SwapChunks {
    fn swap_chunks(&mut self, a: usize, b: usize, width: usize);
}

impl SwapChunks for Vec<u8> {
    fn swap_chunks(&mut self, a: usize, b: usize, width: usize) {
        if a == b {
            return;
        }
        let (lo, hi) = (a.min(b), a.max(b));
        let (left, right) = self.split_at_mut(hi * width);
        left[lo * width..(lo + 1) * width].swap_with_slice(&mut right[..width]);
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            writeln!(f, "  {:3?}", self.row(i))?;
        }
        write!(f, "]")
    }
}

/// `count` pairwise-distinct nonzero field elements (powers of the
/// generator) — the evaluation points for Vandermonde-based constructions.
pub fn distinct_nonzero_points(count: usize) -> Vec<u8> {
    assert!(count <= 255, "GF(2^8) has only 255 nonzero elements");
    (0..count).map(|i| gf_pow(super::tables::GENERATOR, i)).collect()
}

/// Divide helper exposed for decoder pivoting tests.
pub fn normalize_row(row: &mut [u8], pivot: u8) {
    for v in row.iter_mut() {
        *v = gf_div(*v, pivot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    fn random_matrix(p: &mut Prng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zero(r, c);
        for i in 0..r {
            for j in 0..c {
                m.set(i, j, p.next_u32() as u8);
            }
        }
        m
    }

    #[test]
    fn identity_is_neutral() {
        let mut p = Prng::new(1);
        let m = random_matrix(&mut p, 5, 5);
        assert_eq!(m.mul(&Matrix::identity(5)), m);
        assert_eq!(Matrix::identity(5).mul(&m), m);
    }

    #[test]
    fn vandermonde_full_rank() {
        let pts = distinct_nonzero_points(20);
        for rows in [1, 5, 10, 20] {
            let v = Matrix::vandermonde(rows, &pts, 0);
            assert_eq!(v.rank(), rows, "rows={rows}");
            let v1 = Matrix::vandermonde(rows, &pts, 1);
            assert_eq!(v1.rank(), rows, "start=1 rows={rows}");
        }
    }

    #[test]
    fn vandermonde_square_invertible_any_subset() {
        let pts = distinct_nonzero_points(12);
        let v = Matrix::vandermonde(6, &pts, 1);
        let mut p = Prng::new(2);
        for _ in 0..20 {
            let cols = p.choose_distinct(12, 6);
            let sq = v.select_cols(&cols);
            assert!(sq.invert().is_some(), "cols={cols:?}");
        }
    }

    #[test]
    fn cauchy_any_square_submatrix_invertible() {
        let xs: Vec<u8> = (1..=6).collect();
        let ys: Vec<u8> = (10..=30).collect();
        let c = Matrix::cauchy(&xs, &ys);
        let mut p = Prng::new(3);
        for size in 1..=6 {
            for _ in 0..10 {
                let rs = p.choose_distinct(xs.len(), size);
                let cs = p.choose_distinct(ys.len(), size);
                let sub = c.select_rows(&rs).select_cols(&cs);
                assert!(sub.invert().is_some(), "size={size}");
            }
        }
    }

    #[test]
    fn invert_roundtrip_random() {
        let mut p = Prng::new(4);
        let mut found = 0;
        while found < 10 {
            let m = random_matrix(&mut p, 8, 8);
            if let Some(inv) = m.invert() {
                assert_eq!(m.mul(&inv), Matrix::identity(8));
                assert_eq!(inv.mul(&m), Matrix::identity(8));
                found += 1;
            }
        }
    }

    #[test]
    fn singular_matrix_not_invertible() {
        let mut m = Matrix::zero(3, 3);
        m.set(0, 0, 1);
        m.set(1, 1, 1);
        // row 2 all-zero ⇒ singular
        assert!(m.invert().is_none());
        assert_eq!(m.rank(), 2);
    }

    #[test]
    fn rank_of_duplicated_rows() {
        let pts = distinct_nonzero_points(6);
        let v = Matrix::vandermonde(3, &pts, 0);
        let doubled = v.vstack(&v);
        assert_eq!(doubled.rank(), 3);
    }

    #[test]
    fn solve_matches_mul() {
        let mut p = Prng::new(5);
        loop {
            let m = random_matrix(&mut p, 6, 6);
            if let Some(_) = m.invert() {
                let x: Vec<u8> = (0..6).map(|_| p.next_u32() as u8).collect();
                let b = m.mul_vec(&x);
                let solved = m.solve(&b).unwrap();
                assert_eq!(solved, x);
                break;
            }
        }
    }

    #[test]
    fn mul_associative() {
        let mut p = Prng::new(6);
        let a = random_matrix(&mut p, 4, 5);
        let b = random_matrix(&mut p, 5, 3);
        let c = random_matrix(&mut p, 3, 6);
        assert_eq!(a.mul(&b).mul(&c), a.mul(&b.mul(&c)));
    }

    #[test]
    fn stack_and_select() {
        let a = Matrix::from_rows(&[vec![1, 2], vec![3, 4]]);
        let b = Matrix::from_rows(&[vec![5, 6]]);
        let v = a.vstack(&b);
        assert_eq!(v.rows(), 3);
        assert_eq!(v.row(2), &[5, 6]);
        let h = a.hstack(&Matrix::identity(2));
        assert_eq!(h.row(0), &[1, 2, 1, 0]);
        assert_eq!(h.select_cols(&[3, 0]).row(1), &[1, 3]);
        assert_eq!(h.select_rows(&[1]).row(0), &[3, 4, 0, 1]);
    }

    #[test]
    fn xor_only_rows() {
        let m = Matrix::from_rows(&[vec![1, 0, 1, 1], vec![1, 2, 0, 1]]);
        assert!(m.row_is_xor_only(0));
        assert!(!m.row_is_xor_only(1));
    }

    #[test]
    fn mul_vec_matches_mul() {
        let mut p = Prng::new(7);
        let m = random_matrix(&mut p, 5, 7);
        let x: Vec<u8> = (0..7).map(|_| p.next_u32() as u8).collect();
        let as_col = Matrix::from_rows(&x.iter().map(|&v| vec![v]).collect::<Vec<_>>());
        let prod = m.mul(&as_col);
        let v = m.mul_vec(&x);
        for i in 0..5 {
            assert_eq!(prod.get(i, 0), v[i]);
        }
    }

    #[test]
    fn distinct_points_are_distinct() {
        let pts = distinct_nonzero_points(255);
        let mut sorted = pts.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 255);
        assert!(!pts.contains(&0));
    }
}
