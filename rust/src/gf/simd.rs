//! SIMD GF(2^8) kernels — the PSHUFB / TBL technique ISA-L uses (§2.3.3).
//!
//! A constant multiply over GF(2^8) is two 16-entry table lookups (one per
//! nibble) plus an XOR, and `PSHUFB` / `VPSHUFB` / `TBL` perform 16/32 such
//! lookups per instruction. These kernels consume the per-coefficient
//! [`NibbleTables`] shared with the scalar path, so every tier computes
//! byte-identical results (asserted by `tests/gf_simd.rs`).
//!
//! All functions here are `unsafe` only because of `#[target_feature]`:
//! callers must guarantee the instruction set is present (checked once at
//! startup by [`super::dispatch::Kernel::detect`]). Loads and stores are
//! unaligned, so arbitrary slice offsets are fine.

#![allow(dead_code)] // each arch compiles only its own kernels

use super::slice::NibbleTables;

/// Scalar tail shared by every vector kernel: nibble-table multiply for the
/// bytes past the last full vector.
#[inline]
fn tail_mul_acc(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d ^= t.mul(s);
    }
}

#[cfg(target_arch = "x86_64")]
pub mod x86_64 {
    use super::super::slice::NibbleTables;
    use super::tail_mul_acc;
    use std::arch::x86_64::*;

    /// `dst ^= c · src` with 16-byte SSSE3 `PSHUFB` lookups.
    ///
    /// # Safety
    /// The CPU must support SSSE3.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul_acc_ssse3(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm_loadu_si128(t.lo.as_ptr() as *const __m128i);
        let hi = _mm_loadu_si128(t.hi.as_ptr() as *const __m128i);
        let mask = _mm_set1_epi8(0x0F);
        let n = src.len() & !15;
        let mut i = 0;
        while i < n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let d = _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i);
            let pl = _mm_shuffle_epi8(lo, _mm_and_si128(s, mask));
            let ph = _mm_shuffle_epi8(hi, _mm_and_si128(_mm_srli_epi64(s, 4), mask));
            let prod = _mm_xor_si128(pl, ph);
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, _mm_xor_si128(d, prod));
            i += 16;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// `dst ^= c · src` with 32-byte AVX2 `VPSHUFB` lookups (the table is
    /// broadcast to both 128-bit halves, so each half shuffles independently
    /// — exactly the ISA-L `gf_vect_mad` shape).
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_acc_avx2(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm256_broadcastsi128_si256(_mm_loadu_si128(t.lo.as_ptr() as *const __m128i));
        let hi = _mm256_broadcastsi128_si256(_mm_loadu_si128(t.hi.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0F);
        let n = src.len() & !31;
        let mut i = 0;
        while i < n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            let pl = _mm256_shuffle_epi8(lo, _mm256_and_si256(s, mask));
            let ph = _mm256_shuffle_epi8(hi, _mm256_and_si256(_mm256_srli_epi64(s, 4), mask));
            let prod = _mm256_xor_si256(pl, ph);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_xor_si256(d, prod));
            i += 32;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// `dst ^= src` with 32-byte AVX2 loads/stores.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_avx2(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len() & !31;
        let mut i = 0;
        while i < n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_xor_si256(d, s));
            i += 32;
        }
        for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
            *d ^= *s;
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub mod aarch64 {
    use super::super::slice::NibbleTables;
    use super::tail_mul_acc;
    use std::arch::aarch64::*;

    /// `dst ^= c · src` with 16-byte NEON `TBL` (`vqtbl1q_u8`) lookups.
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on AArch64, still detected).
    #[target_feature(enable = "neon")]
    pub unsafe fn mul_acc_neon(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = vld1q_u8(t.lo.as_ptr());
        let hi = vld1q_u8(t.hi.as_ptr());
        let mask = vdupq_n_u8(0x0F);
        let n = src.len() & !15;
        let mut i = 0;
        while i < n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            let pl = vqtbl1q_u8(lo, vandq_u8(s, mask));
            let ph = vqtbl1q_u8(hi, vshrq_n_u8::<4>(s));
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, veorq_u8(pl, ph)));
            i += 16;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// `dst ^= src` with 16-byte NEON loads/stores.
    ///
    /// # Safety
    /// The CPU must support NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_neon(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len() & !15;
        let mut i = 0;
        while i < n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, s));
            i += 16;
        }
        for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
            *d ^= *s;
        }
    }
}
