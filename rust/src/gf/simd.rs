//! SIMD GF(2^8) kernels — the PSHUFB / TBL technique ISA-L uses (§2.3.3).
//!
//! A constant multiply over GF(2^8) is two 16-entry table lookups (one per
//! nibble) plus an XOR, and `PSHUFB` / `VPSHUFB` / `TBL` perform 16/32/64
//! such lookups per instruction; the AVX-512BW tier additionally fuses the
//! XOR accumulate into a single `VPTERNLOGD`, and the GFNI tier replaces
//! the lookups entirely with one `GF2P8AFFINEQB` affine transform per 64
//! bytes (the coefficient's 8×8 bit matrix rides in `NibbleTables::mx`).
//! All kernels consume the per-coefficient [`NibbleTables`] shared with
//! the scalar path, so every tier computes byte-identical results
//! (asserted by `tests/gf_simd.rs`).
//!
//! All functions here are `unsafe` only because of `#[target_feature]`:
//! callers must guarantee the instruction set is present (checked once at
//! startup by [`super::dispatch::Kernel::detect`]). Loads and stores are
//! unaligned, so arbitrary slice offsets are fine.

#![allow(dead_code)] // each arch compiles only its own kernels

use super::slice::NibbleTables;

/// Scalar tail shared by every vector kernel: nibble-table multiply for the
/// bytes past the last full vector.
#[inline]
fn tail_mul_acc(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d ^= t.mul(s);
    }
}

/// Two-source scalar tail for the fused kernels.
#[inline]
fn tail_mul_acc2(t1: &NibbleTables, src1: &[u8], t2: &NibbleTables, src2: &[u8], dst: &mut [u8]) {
    for ((d, &a), &b) in dst.iter_mut().zip(src1).zip(src2) {
        *d ^= t1.mul(a) ^ t2.mul(b);
    }
}

#[cfg(target_arch = "x86_64")]
pub mod x86_64 {
    use super::super::slice::NibbleTables;
    use super::{tail_mul_acc, tail_mul_acc2};
    use std::arch::x86_64::*;

    /// `dst ^= c · src` with 16-byte SSSE3 `PSHUFB` lookups.
    ///
    /// # Safety
    /// The CPU must support SSSE3.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul_acc_ssse3(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm_loadu_si128(t.lo.as_ptr() as *const __m128i);
        let hi = _mm_loadu_si128(t.hi.as_ptr() as *const __m128i);
        let mask = _mm_set1_epi8(0x0F);
        let n = src.len() & !15;
        let mut i = 0;
        while i < n {
            let s = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            let d = _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i);
            let pl = _mm_shuffle_epi8(lo, _mm_and_si128(s, mask));
            let ph = _mm_shuffle_epi8(hi, _mm_and_si128(_mm_srli_epi64(s, 4), mask));
            let prod = _mm_xor_si128(pl, ph);
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, _mm_xor_si128(d, prod));
            i += 16;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// `dst ^= c · src` with 32-byte AVX2 `VPSHUFB` lookups (the table is
    /// broadcast to both 128-bit halves, so each half shuffles independently
    /// — exactly the ISA-L `gf_vect_mad` shape).
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_acc_avx2(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm256_broadcastsi128_si256(_mm_loadu_si128(t.lo.as_ptr() as *const __m128i));
        let hi = _mm256_broadcastsi128_si256(_mm_loadu_si128(t.hi.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0F);
        let n = src.len() & !31;
        let mut i = 0;
        while i < n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            let pl = _mm256_shuffle_epi8(lo, _mm256_and_si256(s, mask));
            let ph = _mm256_shuffle_epi8(hi, _mm256_and_si256(_mm256_srli_epi64(s, 4), mask));
            let prod = _mm256_xor_si256(pl, ph);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_xor_si256(d, prod));
            i += 32;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// Fused `dst ^= c1·src1 ^ c2·src2` with SSSE3 `PSHUFB`: both products
    /// are formed in registers, so `dst` is loaded and stored once per two
    /// sources (halving output traffic versus two `mul_acc` passes).
    ///
    /// # Safety
    /// The CPU must support SSSE3.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn mul_acc2_ssse3(
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        debug_assert_eq!(src1.len(), dst.len());
        debug_assert_eq!(src2.len(), dst.len());
        let lo1 = _mm_loadu_si128(t1.lo.as_ptr() as *const __m128i);
        let hi1 = _mm_loadu_si128(t1.hi.as_ptr() as *const __m128i);
        let lo2 = _mm_loadu_si128(t2.lo.as_ptr() as *const __m128i);
        let hi2 = _mm_loadu_si128(t2.hi.as_ptr() as *const __m128i);
        let mask = _mm_set1_epi8(0x0F);
        let n = dst.len() & !15;
        let mut i = 0;
        while i < n {
            let s1 = _mm_loadu_si128(src1.as_ptr().add(i) as *const __m128i);
            let s2 = _mm_loadu_si128(src2.as_ptr().add(i) as *const __m128i);
            let d = _mm_loadu_si128(dst.as_ptr().add(i) as *const __m128i);
            let p1 = _mm_xor_si128(
                _mm_shuffle_epi8(lo1, _mm_and_si128(s1, mask)),
                _mm_shuffle_epi8(hi1, _mm_and_si128(_mm_srli_epi64(s1, 4), mask)),
            );
            let p2 = _mm_xor_si128(
                _mm_shuffle_epi8(lo2, _mm_and_si128(s2, mask)),
                _mm_shuffle_epi8(hi2, _mm_and_si128(_mm_srli_epi64(s2, 4), mask)),
            );
            _mm_storeu_si128(
                dst.as_mut_ptr().add(i) as *mut __m128i,
                _mm_xor_si128(d, _mm_xor_si128(p1, p2)),
            );
            i += 16;
        }
        tail_mul_acc2(t1, &src1[n..], t2, &src2[n..], &mut dst[n..]);
    }

    /// Fused `dst ^= c1·src1 ^ c2·src2` with 32-byte AVX2 `VPSHUFB` — the
    /// `gf_2vect_mad` shape: one `dst` load/store per two sources.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_acc2_avx2(
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        debug_assert_eq!(src1.len(), dst.len());
        debug_assert_eq!(src2.len(), dst.len());
        let lo1 = _mm256_broadcastsi128_si256(_mm_loadu_si128(t1.lo.as_ptr() as *const __m128i));
        let hi1 = _mm256_broadcastsi128_si256(_mm_loadu_si128(t1.hi.as_ptr() as *const __m128i));
        let lo2 = _mm256_broadcastsi128_si256(_mm_loadu_si128(t2.lo.as_ptr() as *const __m128i));
        let hi2 = _mm256_broadcastsi128_si256(_mm_loadu_si128(t2.hi.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0F);
        let n = dst.len() & !31;
        let mut i = 0;
        while i < n {
            let s1 = _mm256_loadu_si256(src1.as_ptr().add(i) as *const __m256i);
            let s2 = _mm256_loadu_si256(src2.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            let p1 = _mm256_xor_si256(
                _mm256_shuffle_epi8(lo1, _mm256_and_si256(s1, mask)),
                _mm256_shuffle_epi8(hi1, _mm256_and_si256(_mm256_srli_epi64(s1, 4), mask)),
            );
            let p2 = _mm256_xor_si256(
                _mm256_shuffle_epi8(lo2, _mm256_and_si256(s2, mask)),
                _mm256_shuffle_epi8(hi2, _mm256_and_si256(_mm256_srli_epi64(s2, 4), mask)),
            );
            _mm256_storeu_si256(
                dst.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_xor_si256(d, _mm256_xor_si256(p1, p2)),
            );
            i += 32;
        }
        tail_mul_acc2(t1, &src1[n..], t2, &src2[n..], &mut dst[n..]);
    }

    /// `dst ^= c · src` with 64-byte AVX-512BW `VPSHUFB` lookups: the
    /// nibble tables are broadcast to all four 128-bit lanes, and the
    /// accumulate `d ^ pl ^ ph` is a single `VPTERNLOGD` (imm `0x96` =
    /// three-way XOR) instead of two vector XORs.
    ///
    /// # Safety
    /// The CPU must support AVX-512F and AVX-512BW.
    #[target_feature(enable = "avx512f,avx512bw")]
    pub unsafe fn mul_acc_avx512(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm512_broadcast_i32x4(_mm_loadu_si128(t.lo.as_ptr() as *const __m128i));
        let hi = _mm512_broadcast_i32x4(_mm_loadu_si128(t.hi.as_ptr() as *const __m128i));
        let mask = _mm512_set1_epi8(0x0F);
        let n = src.len() & !63;
        let mut i = 0;
        while i < n {
            let s = _mm512_loadu_epi8(src.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(dst.as_ptr().add(i) as *const i8);
            let pl = _mm512_shuffle_epi8(lo, _mm512_and_si512(s, mask));
            let ph = _mm512_shuffle_epi8(hi, _mm512_and_si512(_mm512_srli_epi64::<4>(s), mask));
            _mm512_storeu_epi8(
                dst.as_mut_ptr().add(i) as *mut i8,
                _mm512_ternarylogic_epi32::<0x96>(d, pl, ph),
            );
            i += 64;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// Fused `dst ^= c1·src1 ^ c2·src2` with 64-byte AVX-512BW `VPSHUFB` —
    /// the `gf_2vect_mad` shape at 512-bit width: one `dst` load/store per
    /// two sources, two `VPTERNLOGD`s for the four-way XOR accumulate.
    ///
    /// # Safety
    /// The CPU must support AVX-512F and AVX-512BW.
    #[target_feature(enable = "avx512f,avx512bw")]
    pub unsafe fn mul_acc2_avx512(
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        debug_assert_eq!(src1.len(), dst.len());
        debug_assert_eq!(src2.len(), dst.len());
        let lo1 = _mm512_broadcast_i32x4(_mm_loadu_si128(t1.lo.as_ptr() as *const __m128i));
        let hi1 = _mm512_broadcast_i32x4(_mm_loadu_si128(t1.hi.as_ptr() as *const __m128i));
        let lo2 = _mm512_broadcast_i32x4(_mm_loadu_si128(t2.lo.as_ptr() as *const __m128i));
        let hi2 = _mm512_broadcast_i32x4(_mm_loadu_si128(t2.hi.as_ptr() as *const __m128i));
        let mask = _mm512_set1_epi8(0x0F);
        let n = dst.len() & !63;
        let mut i = 0;
        while i < n {
            let s1 = _mm512_loadu_epi8(src1.as_ptr().add(i) as *const i8);
            let s2 = _mm512_loadu_epi8(src2.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(dst.as_ptr().add(i) as *const i8);
            let p1l = _mm512_shuffle_epi8(lo1, _mm512_and_si512(s1, mask));
            let p1h = _mm512_shuffle_epi8(hi1, _mm512_and_si512(_mm512_srli_epi64::<4>(s1), mask));
            let p2l = _mm512_shuffle_epi8(lo2, _mm512_and_si512(s2, mask));
            let p2h = _mm512_shuffle_epi8(hi2, _mm512_and_si512(_mm512_srli_epi64::<4>(s2), mask));
            let acc = _mm512_ternarylogic_epi32::<0x96>(d, p1l, p1h);
            _mm512_storeu_epi8(
                dst.as_mut_ptr().add(i) as *mut i8,
                _mm512_ternarylogic_epi32::<0x96>(acc, p2l, p2h),
            );
            i += 64;
        }
        tail_mul_acc2(t1, &src1[n..], t2, &src2[n..], &mut dst[n..]);
    }

    /// `dst ^= c · src` with GFNI: one 64-byte `GF2P8AFFINEQB` forms all 64
    /// products at once — the per-coefficient 8×8 bit matrix rides in
    /// [`NibbleTables::mx`] — and a `VPTERNLOGD`-free XOR accumulates.
    /// No table broadcasts, no nibble split: 2 instructions per 64 bytes.
    ///
    /// # Safety
    /// The CPU must support GFNI, AVX-512F and AVX-512BW.
    #[target_feature(enable = "gfni,avx512f,avx512bw")]
    pub unsafe fn mul_acc_gfni(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let a = _mm512_set1_epi64(t.mx as i64);
        let n = src.len() & !63;
        let mut i = 0;
        while i < n {
            let s = _mm512_loadu_epi8(src.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(dst.as_ptr().add(i) as *const i8);
            let prod = _mm512_gf2p8affine_epi64_epi8::<0>(s, a);
            _mm512_storeu_epi8(dst.as_mut_ptr().add(i) as *mut i8, _mm512_xor_si512(d, prod));
            i += 64;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// Fused `dst ^= c1·src1 ^ c2·src2` with GFNI: two affine transforms
    /// and one `VPTERNLOGD` per 64 output bytes — `dst` is loaded and
    /// stored once per two sources.
    ///
    /// # Safety
    /// The CPU must support GFNI, AVX-512F and AVX-512BW.
    #[target_feature(enable = "gfni,avx512f,avx512bw")]
    pub unsafe fn mul_acc2_gfni(
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        debug_assert_eq!(src1.len(), dst.len());
        debug_assert_eq!(src2.len(), dst.len());
        let a1 = _mm512_set1_epi64(t1.mx as i64);
        let a2 = _mm512_set1_epi64(t2.mx as i64);
        let n = dst.len() & !63;
        let mut i = 0;
        while i < n {
            let s1 = _mm512_loadu_epi8(src1.as_ptr().add(i) as *const i8);
            let s2 = _mm512_loadu_epi8(src2.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(dst.as_ptr().add(i) as *const i8);
            let p1 = _mm512_gf2p8affine_epi64_epi8::<0>(s1, a1);
            let p2 = _mm512_gf2p8affine_epi64_epi8::<0>(s2, a2);
            _mm512_storeu_epi8(
                dst.as_mut_ptr().add(i) as *mut i8,
                _mm512_ternarylogic_epi32::<0x96>(d, p1, p2),
            );
            i += 64;
        }
        tail_mul_acc2(t1, &src1[n..], t2, &src2[n..], &mut dst[n..]);
    }

    /// `dst ^= src` with 64-byte AVX-512BW loads/stores (shared by the
    /// `avx512` and `gfni` tiers — XOR has no multiply to accelerate).
    ///
    /// # Safety
    /// The CPU must support AVX-512F and AVX-512BW.
    #[target_feature(enable = "avx512f,avx512bw")]
    pub unsafe fn xor_avx512(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len() & !63;
        let mut i = 0;
        while i < n {
            let s = _mm512_loadu_epi8(src.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(dst.as_ptr().add(i) as *const i8);
            _mm512_storeu_epi8(dst.as_mut_ptr().add(i) as *mut i8, _mm512_xor_si512(d, s));
            i += 64;
        }
        for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
            *d ^= *s;
        }
    }

    /// `dst ^= src` with 32-byte AVX2 loads/stores.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_avx2(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len() & !31;
        let mut i = 0;
        while i < n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_xor_si256(d, s));
            i += 32;
        }
        for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
            *d ^= *s;
        }
    }

    // ------------------------------------------------------------------
    // Non-temporal (streaming) store kernels.
    //
    // When an output span exceeds the LLC, regular stores cost a
    // read-for-ownership (the line is fetched from DRAM just to be fully
    // overwritten) and evict useful lines on the way out. `MOVNTDQ`-class
    // streaming stores write through combining buffers straight to DRAM:
    // no RFO, no pollution — the classic last ~1.5–2× in ISA-L-style
    // libraries once the multiplies are already table/affine-cheap.
    //
    // Streaming stores never *read* `dst`, so every NT kernel here is a
    // pure producer: `copy_nt` (dst = src), `xor_nt` (dst = a ^ b) and
    // `mul_into_nt` (dst = acc ^ c·src). The dispatch layer computes
    // accumulations in a cache-resident pooled scratch with the regular
    // kernels and fuses only the *final* pass into one of these, so the
    // big output is written exactly once, straight to memory. XOR is
    // associative and every tier shares the scalar tails, so results stay
    // byte-identical to the regular path (fuzzed in tests/gf_simd.rs).
    //
    // Streaming stores require aligned addresses: pooled buffers are
    // 64-byte aligned by construction, but arbitrary dst offsets are still
    // handled — a scalar head runs up to the first aligned byte, a scalar
    // tail after the last full vector, and an `sfence` orders the weakly
    // ordered stores before the batch latch publishes the buffer.
    // ------------------------------------------------------------------

    /// Scalar `dst = a ^ b` for NT head/tail spans.
    #[inline]
    fn xor2_scalar(dst: &mut [u8], a: &[u8], b: &[u8]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d = x ^ y;
        }
    }

    /// Scalar `dst = acc ^ c·src` for NT head/tail spans.
    #[inline]
    fn mul_into_scalar(t: &NibbleTables, src: &[u8], acc: &[u8], dst: &mut [u8]) {
        for ((d, &s), &a) in dst.iter_mut().zip(src).zip(acc) {
            *d = a ^ t.mul(s);
        }
    }

    /// `dst = src` with 32-byte streaming stores.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_nt_avx2(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let len = dst.len();
        let head = dst.as_ptr().align_offset(32).min(len);
        dst[..head].copy_from_slice(&src[..head]);
        let n = head + ((len - head) & !31);
        let mut i = head;
        while i < n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            _mm256_stream_si256(dst.as_mut_ptr().add(i) as *mut __m256i, s);
            i += 32;
        }
        dst[n..].copy_from_slice(&src[n..]);
        _mm_sfence();
    }

    /// `dst = a ^ b` with 32-byte streaming stores (dst is never read).
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_nt_avx2(dst: &mut [u8], a: &[u8], b: &[u8]) {
        debug_assert_eq!(a.len(), dst.len());
        debug_assert_eq!(b.len(), dst.len());
        let len = dst.len();
        let head = dst.as_ptr().align_offset(32).min(len);
        xor2_scalar(&mut dst[..head], &a[..head], &b[..head]);
        let n = head + ((len - head) & !31);
        let mut i = head;
        while i < n {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
            _mm256_stream_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_xor_si256(va, vb));
            i += 32;
        }
        xor2_scalar(&mut dst[n..], &a[n..], &b[n..]);
        _mm_sfence();
    }

    /// `dst = acc ^ c·src` with AVX2 `VPSHUFB` products and 32-byte
    /// streaming stores: the accumulator is loaded normally (it is the
    /// cache-resident scratch), the output is written straight to memory.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_into_nt_avx2(t: &NibbleTables, src: &[u8], acc: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        debug_assert_eq!(acc.len(), dst.len());
        let lo = _mm256_broadcastsi128_si256(_mm_loadu_si128(t.lo.as_ptr() as *const __m128i));
        let hi = _mm256_broadcastsi128_si256(_mm_loadu_si128(t.hi.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0F);
        let len = dst.len();
        let head = dst.as_ptr().align_offset(32).min(len);
        mul_into_scalar(t, &src[..head], &acc[..head], &mut dst[..head]);
        let n = head + ((len - head) & !31);
        let mut i = head;
        while i < n {
            let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            let d = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            let pl = _mm256_shuffle_epi8(lo, _mm256_and_si256(s, mask));
            let ph = _mm256_shuffle_epi8(hi, _mm256_and_si256(_mm256_srli_epi64(s, 4), mask));
            let out = _mm256_xor_si256(d, _mm256_xor_si256(pl, ph));
            _mm256_stream_si256(dst.as_mut_ptr().add(i) as *mut __m256i, out);
            i += 32;
        }
        mul_into_scalar(t, &src[n..], &acc[n..], &mut dst[n..]);
        _mm_sfence();
    }

    /// Stream a 512-bit value as two 32-byte `MOVNTDQ` halves (adjacent
    /// streams to one cacheline merge in the write-combining buffer, so
    /// this fills whole lines like a 512-bit stream would).
    ///
    /// # Safety
    /// The CPU must support AVX-512F; `p` must be 32-byte aligned with 64
    /// writable bytes.
    #[target_feature(enable = "avx512f")]
    unsafe fn stream512(p: *mut u8, v: __m512i) {
        _mm256_stream_si256(p as *mut __m256i, _mm512_castsi512_si256(v));
        _mm256_stream_si256(p.add(32) as *mut __m256i, _mm512_extracti64x4_epi64::<1>(v));
    }

    /// `dst = src` with 64-byte loads and streaming stores (shared by the
    /// `avx512` and `gfni` tiers).
    ///
    /// # Safety
    /// The CPU must support AVX-512F and AVX-512BW.
    #[target_feature(enable = "avx512f,avx512bw")]
    pub unsafe fn copy_nt_avx512(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let len = dst.len();
        let head = dst.as_ptr().align_offset(64).min(len);
        dst[..head].copy_from_slice(&src[..head]);
        let n = head + ((len - head) & !63);
        let mut i = head;
        while i < n {
            let s = _mm512_loadu_epi8(src.as_ptr().add(i) as *const i8);
            stream512(dst.as_mut_ptr().add(i), s);
            i += 64;
        }
        dst[n..].copy_from_slice(&src[n..]);
        _mm_sfence();
    }

    /// `dst = a ^ b` with 64-byte loads and streaming stores (shared by
    /// the `avx512` and `gfni` tiers; dst is never read).
    ///
    /// # Safety
    /// The CPU must support AVX-512F and AVX-512BW.
    #[target_feature(enable = "avx512f,avx512bw")]
    pub unsafe fn xor_nt_avx512(dst: &mut [u8], a: &[u8], b: &[u8]) {
        debug_assert_eq!(a.len(), dst.len());
        debug_assert_eq!(b.len(), dst.len());
        let len = dst.len();
        let head = dst.as_ptr().align_offset(64).min(len);
        xor2_scalar(&mut dst[..head], &a[..head], &b[..head]);
        let n = head + ((len - head) & !63);
        let mut i = head;
        while i < n {
            let va = _mm512_loadu_epi8(a.as_ptr().add(i) as *const i8);
            let vb = _mm512_loadu_epi8(b.as_ptr().add(i) as *const i8);
            stream512(dst.as_mut_ptr().add(i), _mm512_xor_si512(va, vb));
            i += 64;
        }
        xor2_scalar(&mut dst[n..], &a[n..], &b[n..]);
        _mm_sfence();
    }

    /// `dst = acc ^ c·src` with AVX-512BW `VPSHUFB` products and streaming
    /// stores.
    ///
    /// # Safety
    /// The CPU must support AVX-512F and AVX-512BW.
    #[target_feature(enable = "avx512f,avx512bw")]
    pub unsafe fn mul_into_nt_avx512(t: &NibbleTables, src: &[u8], acc: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        debug_assert_eq!(acc.len(), dst.len());
        let lo = _mm512_broadcast_i32x4(_mm_loadu_si128(t.lo.as_ptr() as *const __m128i));
        let hi = _mm512_broadcast_i32x4(_mm_loadu_si128(t.hi.as_ptr() as *const __m128i));
        let mask = _mm512_set1_epi8(0x0F);
        let len = dst.len();
        let head = dst.as_ptr().align_offset(64).min(len);
        mul_into_scalar(t, &src[..head], &acc[..head], &mut dst[..head]);
        let n = head + ((len - head) & !63);
        let mut i = head;
        while i < n {
            let s = _mm512_loadu_epi8(src.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(acc.as_ptr().add(i) as *const i8);
            let pl = _mm512_shuffle_epi8(lo, _mm512_and_si512(s, mask));
            let ph = _mm512_shuffle_epi8(hi, _mm512_and_si512(_mm512_srli_epi64::<4>(s), mask));
            stream512(dst.as_mut_ptr().add(i), _mm512_ternarylogic_epi32::<0x96>(d, pl, ph));
            i += 64;
        }
        mul_into_scalar(t, &src[n..], &acc[n..], &mut dst[n..]);
        _mm_sfence();
    }

    /// `dst = acc ^ c·src` with one `GF2P8AFFINEQB` per 64 bytes and
    /// streaming stores.
    ///
    /// # Safety
    /// The CPU must support GFNI, AVX-512F and AVX-512BW.
    #[target_feature(enable = "gfni,avx512f,avx512bw")]
    pub unsafe fn mul_into_nt_gfni(t: &NibbleTables, src: &[u8], acc: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        debug_assert_eq!(acc.len(), dst.len());
        let a = _mm512_set1_epi64(t.mx as i64);
        let len = dst.len();
        let head = dst.as_ptr().align_offset(64).min(len);
        mul_into_scalar(t, &src[..head], &acc[..head], &mut dst[..head]);
        let n = head + ((len - head) & !63);
        let mut i = head;
        while i < n {
            let s = _mm512_loadu_epi8(src.as_ptr().add(i) as *const i8);
            let d = _mm512_loadu_epi8(acc.as_ptr().add(i) as *const i8);
            let prod = _mm512_gf2p8affine_epi64_epi8::<0>(s, a);
            stream512(dst.as_mut_ptr().add(i), _mm512_xor_si512(d, prod));
            i += 64;
        }
        mul_into_scalar(t, &src[n..], &acc[n..], &mut dst[n..]);
        _mm_sfence();
    }
}

#[cfg(target_arch = "aarch64")]
pub mod aarch64 {
    use super::super::slice::NibbleTables;
    use super::{tail_mul_acc, tail_mul_acc2};
    use std::arch::aarch64::*;

    /// `dst ^= c · src` with 16-byte NEON `TBL` (`vqtbl1q_u8`) lookups.
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on AArch64, still detected).
    #[target_feature(enable = "neon")]
    pub unsafe fn mul_acc_neon(t: &NibbleTables, src: &[u8], dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let lo = vld1q_u8(t.lo.as_ptr());
        let hi = vld1q_u8(t.hi.as_ptr());
        let mask = vdupq_n_u8(0x0F);
        let n = src.len() & !15;
        let mut i = 0;
        while i < n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            let pl = vqtbl1q_u8(lo, vandq_u8(s, mask));
            let ph = vqtbl1q_u8(hi, vshrq_n_u8::<4>(s));
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, veorq_u8(pl, ph)));
            i += 16;
        }
        tail_mul_acc(t, &src[n..], &mut dst[n..]);
    }

    /// Fused `dst ^= c1·src1 ^ c2·src2` with NEON `TBL`: one `dst`
    /// load/store per two sources.
    ///
    /// # Safety
    /// The CPU must support NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn mul_acc2_neon(
        t1: &NibbleTables,
        src1: &[u8],
        t2: &NibbleTables,
        src2: &[u8],
        dst: &mut [u8],
    ) {
        debug_assert_eq!(src1.len(), dst.len());
        debug_assert_eq!(src2.len(), dst.len());
        let lo1 = vld1q_u8(t1.lo.as_ptr());
        let hi1 = vld1q_u8(t1.hi.as_ptr());
        let lo2 = vld1q_u8(t2.lo.as_ptr());
        let hi2 = vld1q_u8(t2.hi.as_ptr());
        let mask = vdupq_n_u8(0x0F);
        let n = dst.len() & !15;
        let mut i = 0;
        while i < n {
            let s1 = vld1q_u8(src1.as_ptr().add(i));
            let s2 = vld1q_u8(src2.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            let p1 = veorq_u8(
                vqtbl1q_u8(lo1, vandq_u8(s1, mask)),
                vqtbl1q_u8(hi1, vshrq_n_u8::<4>(s1)),
            );
            let p2 = veorq_u8(
                vqtbl1q_u8(lo2, vandq_u8(s2, mask)),
                vqtbl1q_u8(hi2, vshrq_n_u8::<4>(s2)),
            );
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, veorq_u8(p1, p2)));
            i += 16;
        }
        tail_mul_acc2(t1, &src1[n..], t2, &src2[n..], &mut dst[n..]);
    }

    /// `dst ^= src` with 16-byte NEON loads/stores.
    ///
    /// # Safety
    /// The CPU must support NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_neon(dst: &mut [u8], src: &[u8]) {
        debug_assert_eq!(src.len(), dst.len());
        let n = src.len() & !15;
        let mut i = 0;
        while i < n {
            let s = vld1q_u8(src.as_ptr().add(i));
            let d = vld1q_u8(dst.as_ptr().add(i));
            vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(d, s));
            i += 16;
        }
        for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
            *d ^= *s;
        }
    }
}
