//! Reusable block-buffer pool.
//!
//! Every repair used to allocate fresh `vec![0u8; block_size]` outputs —
//! at 1 MB blocks that is a page-faulting allocation per rebuilt block, on
//! the hottest path in the system. The pool recycles those buffers:
//! [`take_zeroed`] reuses a warm allocation when one is available (the
//! `resize` re-zeroes it, which touches already-mapped pages), and
//! [`recycle`] returns a buffer once its contents are consumed.
//!
//! The pool is a bounded LIFO — deliberately simple: buffers of any size
//! mix freely (capacity is checked on reuse), and at most [`MAX_POOLED`]
//! buffers are retained so a burst of large repairs cannot pin memory.

use std::sync::Mutex;

/// Retention bound: enough for a full-node recovery fan-out, small enough
/// that the pool holds at most ~64 MB of 1 MB blocks.
const MAX_POOLED: usize = 64;

/// A bounded pool of byte buffers.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<u8>>>,
    max: usize,
}

impl BufferPool {
    pub const fn new(max: usize) -> BufferPool {
        BufferPool { bufs: Mutex::new(Vec::new()), max }
    }

    /// A zeroed buffer of exactly `len` bytes, reusing a pooled allocation
    /// with sufficient capacity when possible. Undersized pooled buffers
    /// are left in place — consuming one would reallocate anyway while
    /// starving future smaller requests.
    pub fn take_zeroed(&self, len: usize) -> Vec<u8> {
        let reused = {
            let mut bufs = self.bufs.lock().unwrap();
            bufs.iter().rposition(|b| b.capacity() >= len).map(|i| bufs.swap_remove(i))
        };
        match reused {
            Some(mut b) => {
                b.clear();
                b.resize(len, 0);
                b
            }
            None => vec![0u8; len],
        }
    }

    /// A buffer of exactly `len` bytes whose contents are **unspecified**
    /// (stale data from a previous use) — for consumers that overwrite
    /// every byte before reading (fold's `copy_from_slice`, matmul's
    /// `fill(0)` + accumulate). Skips the re-zeroing pass of
    /// [`Self::take_zeroed`], which is pure overhead on those paths. Only
    /// already-initialized pooled bytes are reused (`b.len() >= len`), so
    /// no uninitialized memory is ever exposed.
    pub fn take_for_overwrite(&self, len: usize) -> Vec<u8> {
        let reused = {
            let mut bufs = self.bufs.lock().unwrap();
            bufs.iter().rposition(|b| b.len() >= len).map(|i| bufs.swap_remove(i))
        };
        match reused {
            Some(mut b) => {
                b.truncate(len);
                b
            }
            None => vec![0u8; len],
        }
    }

    /// Return a buffer to the pool (dropped if the pool is full or the
    /// buffer has no backing allocation).
    pub fn recycle(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max {
            bufs.push(buf);
        }
    }

    /// Buffers currently pooled (for tests / introspection).
    pub fn len(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

static GLOBAL: BufferPool = BufferPool::new(MAX_POOLED);

/// The process-wide pool used by the decode and proxy paths.
pub fn global() -> &'static BufferPool {
    &GLOBAL
}

/// [`BufferPool::take_zeroed`] on the process-wide pool.
pub fn take_zeroed(len: usize) -> Vec<u8> {
    GLOBAL.take_zeroed(len)
}

/// [`BufferPool::take_for_overwrite`] on the process-wide pool.
pub fn take_for_overwrite(len: usize) -> Vec<u8> {
    GLOBAL.take_for_overwrite(len)
}

/// [`BufferPool::recycle`] on the process-wide pool.
pub fn recycle(buf: Vec<u8>) {
    GLOBAL.recycle(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_after_recycle() {
        let pool = BufferPool::new(4);
        let mut b = pool.take_zeroed(100);
        b.iter_mut().for_each(|x| *x = 0xAB);
        pool.recycle(b);
        let b2 = pool.take_zeroed(50);
        assert_eq!(b2.len(), 50);
        assert!(b2.iter().all(|&x| x == 0), "reused buffer must be re-zeroed");
    }

    #[test]
    fn reuses_allocation() {
        let pool = BufferPool::new(4);
        let b = pool.take_zeroed(1024);
        let ptr = b.as_ptr();
        pool.recycle(b);
        let b2 = pool.take_zeroed(512);
        assert_eq!(b2.as_ptr(), ptr, "should reuse the pooled allocation");
    }

    #[test]
    fn bounded_retention() {
        let pool = BufferPool::new(2);
        for _ in 0..5 {
            pool.recycle(vec![0u8; 16]);
        }
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn large_request_does_not_consume_small_buffers() {
        let pool = BufferPool::new(4);
        pool.recycle(vec![0u8; 64]);
        let b = pool.take_zeroed(1024); // no pooled buffer fits → fresh alloc
        assert_eq!(b.len(), 1024);
        assert_eq!(pool.len(), 1, "undersized buffer must stay pooled");
    }

    #[test]
    fn take_for_overwrite_reuses_without_zeroing() {
        let pool = BufferPool::new(4);
        let mut b = pool.take_zeroed(128);
        b.iter_mut().for_each(|x| *x = 0xCD);
        let ptr = b.as_ptr();
        pool.recycle(b);
        let b2 = pool.take_for_overwrite(100);
        assert_eq!(b2.len(), 100);
        assert_eq!(b2.as_ptr(), ptr, "must reuse the pooled allocation");
        assert!(b2.iter().all(|&x| x == 0xCD), "contents intentionally stale");
        // an oversized request can't reuse the (shorter) pooled contents
        pool.recycle(b2);
        let b3 = pool.take_for_overwrite(4096);
        assert_eq!(b3.len(), 4096);
        assert!(b3.iter().all(|&x| x == 0), "fresh allocation is zeroed");
    }

    #[test]
    fn zero_len_take_ok() {
        let pool = BufferPool::new(2);
        let b = pool.take_zeroed(0);
        assert!(b.is_empty());
        pool.recycle(b); // capacity 0 — silently dropped
        assert_eq!(pool.len(), 0);
    }
}
