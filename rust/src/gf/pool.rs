//! Aligned, size-classed, sharded block-buffer pool.
//!
//! Every repair used to allocate fresh `vec![0u8; block_size]` outputs —
//! at 1 MB blocks that is a page-faulting allocation per rebuilt block, on
//! the hottest path in the system. The pool recycles those buffers, and
//! unlike the original single-`Mutex` LIFO it is built for the memory
//! system the SIMD kernels now saturate:
//!
//! * **Alignment.** Every buffer is allocated at [`ALIGN`] (cacheline)
//!   alignment via [`std::alloc::Layout`], so non-temporal stores land on
//!   aligned vectors from byte 0 and lanes never split a cacheline. A
//!   `Vec<u8>` cannot promise this, so buffers are carried by the owning
//!   [`PooledBuf`] type (deref's to `[u8]`, so call sites read the same).
//! * **Size classes.** Capacities are power-of-two classes (min
//!   [`MIN_CLASS`]), so a request only ever reuses a buffer from its own
//!   class: a burst of 1 MiB repairs can no longer starve 64 KiB lane
//!   buffers out of the pool, and worst-case internal slack is bounded at
//!   2×.
//! * **Sharding.** Buffers live in [`SHARDS`] independently locked shards,
//!   indexed per thread, so eight workers recycling lane outputs stop
//!   serializing on one global lock. A take that misses its home shard
//!   probes the others before allocating (misses pay a fault anyway).
//! * **Bytes cap.** Retention is capped by total retained *bytes* (the old
//!   pool capped only buffer count, so one burst of huge blocks could pin
//!   ~unbounded memory forever). Overflow drops the buffer back to the
//!   allocator and counts it.
//!
//! The process-wide free functions ([`take_zeroed`], [`take_for_overwrite`],
//! [`recycle`]) additionally keep a tiny per-thread cache of small buffers
//! in front of the shards, so the per-lane take/recycle pairs inside one
//! worker never touch a lock at all. The thread cache holds at most
//! [`TLS_MAX_ENTRIES`] buffers of at most [`TLS_MAX_CLASS_BYTES`] each and
//! is *not* counted against the shared bytes cap — a documented, bounded
//! slack of `threads × 4 × 256 KiB`.
//!
//! Hit/miss/drop counters are surfaced by `unilrc engine` (see
//! [`PoolStats`]) so bench runs are self-describing.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::cell::RefCell;
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Buffer alignment: one cacheline, which is also the widest vector the
/// kernels store (64 B = one AVX-512 lane), so aligned non-temporal stores
/// work from byte 0 of every pooled buffer.
pub const ALIGN: usize = 64;

/// Smallest size class. Requests below this round up to it.
const MIN_CLASS: usize = 1 << 10;

/// Number of power-of-two classes: 1 KiB … 2 GiB.
const NUM_CLASSES: usize = 22;

/// Number of independently locked shards.
const SHARDS: usize = 8;

/// Default total-retained-bytes cap for the process-wide pool: enough for
/// a full-node recovery fan-out of 1 MiB blocks with headroom.
const DEFAULT_BYTES_CAP: usize = 128 << 20;

/// Largest class the per-thread cache will hold.
const TLS_MAX_CLASS_BYTES: usize = 256 << 10;

/// Per-thread cache entries.
const TLS_MAX_ENTRIES: usize = 4;

/// An owned, [`ALIGN`]-aligned byte buffer whose capacity is a pool size
/// class. Deref's to `[u8]`, so it reads like a `Vec<u8>` at call sites;
/// the distinct type exists because a `Vec` built over an over-aligned
/// allocation would deallocate with the wrong layout (UB).
///
/// Every byte in `[0, cap)` is zero-initialized at allocation, which is
/// what lets the pool hand back reused buffers at any `len ≤ cap` without
/// ever exposing uninitialized memory.
pub struct PooledBuf {
    ptr: NonNull<u8>,
    len: usize,
    cap: usize,
}

// SAFETY: PooledBuf owns its allocation exclusively (no aliasing), so it
// is Send/Sync exactly like Vec<u8>.
unsafe impl Send for PooledBuf {}
unsafe impl Sync for PooledBuf {}

/// Class capacity for a requested length.
fn class_bytes(len: usize) -> usize {
    len.next_power_of_two().max(MIN_CLASS)
}

/// Class index for an exact class capacity; `None` when the capacity is
/// not poolable (zero, not a class size, or beyond the largest class).
fn class_index(cap: usize) -> Option<usize> {
    if cap < MIN_CLASS || !cap.is_power_of_two() {
        return None;
    }
    let idx = (cap / MIN_CLASS).trailing_zeros() as usize;
    (idx < NUM_CLASSES).then_some(idx)
}

impl PooledBuf {
    /// An empty buffer with no backing allocation.
    pub const fn empty() -> PooledBuf {
        PooledBuf { ptr: NonNull::dangling(), len: 0, cap: 0 }
    }

    /// Allocate a fresh zeroed buffer of `len` bytes at class capacity.
    fn alloc_class(len: usize) -> PooledBuf {
        if len == 0 {
            return PooledBuf::empty();
        }
        let cap = class_bytes(len);
        let layout = Layout::from_size_align(cap, ALIGN).expect("pool buffer layout");
        // SAFETY: layout has non-zero size (cap >= MIN_CLASS).
        let raw = unsafe { alloc_zeroed(layout) };
        let Some(ptr) = NonNull::new(raw) else { handle_alloc_error(layout) };
        PooledBuf { ptr, len, cap }
    }

    /// An aligned copy of `data`.
    pub fn from_slice(data: &[u8]) -> PooledBuf {
        let mut b = PooledBuf::alloc_class(data.len());
        b.as_mut_slice().copy_from_slice(data);
        b
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Class capacity of the backing allocation.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn as_slice(&self) -> &[u8] {
        self
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        self
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }

    /// Shrink or grow within the already-initialized class capacity
    /// (contents beyond the old `len` are whatever a previous user wrote —
    /// initialized, but stale).
    fn set_len_within_cap(&mut self, len: usize) {
        debug_assert!(len <= self.cap);
        self.len = len;
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if self.cap != 0 {
            let layout = Layout::from_size_align(self.cap, ALIGN).expect("pool buffer layout");
            // SAFETY: ptr was allocated with exactly this layout.
            unsafe { dealloc(self.ptr.as_ptr(), layout) }
        }
    }
}

impl Deref for PooledBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        // SAFETY: [0, len) is allocated, initialized, and exclusively owned.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut [u8] {
        // SAFETY: as above, with &mut self guaranteeing unique access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl AsRef<[u8]> for PooledBuf {
    fn as_ref(&self) -> &[u8] {
        self
    }
}

impl AsMut<[u8]> for PooledBuf {
    fn as_mut(&mut self) -> &mut [u8] {
        self
    }
}

impl Clone for PooledBuf {
    fn clone(&self) -> PooledBuf {
        PooledBuf::from_slice(self)
    }
}

impl Default for PooledBuf {
    fn default() -> PooledBuf {
        PooledBuf::empty()
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(self.as_slice(), f)
    }
}

impl PartialEq for PooledBuf {
    fn eq(&self, other: &PooledBuf) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for PooledBuf {}

impl PartialEq<Vec<u8>> for PooledBuf {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<PooledBuf> for Vec<u8> {
    fn eq(&self, other: &PooledBuf) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[u8]> for PooledBuf {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for PooledBuf {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl From<&[u8]> for PooledBuf {
    fn from(data: &[u8]) -> PooledBuf {
        PooledBuf::from_slice(data)
    }
}

impl From<PooledBuf> for Vec<u8> {
    fn from(b: PooledBuf) -> Vec<u8> {
        b.to_vec()
    }
}

/// Pool counters, surfaced by `unilrc engine`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Takes served from a pooled buffer (shard or thread cache).
    pub hits: u64,
    /// Takes that fell through to a fresh allocation.
    pub misses: u64,
    /// Recycles dropped because the bytes cap (or class range) was hit.
    pub drops: u64,
    /// Recycles accepted back into the pool.
    pub recycled: u64,
    /// Bytes currently retained in the shards (thread caches excluded).
    pub retained_bytes: usize,
    /// Buffers currently retained in the shards.
    pub buffers: usize,
}

/// The sharded size-classed pool. Shards are indexed by a per-thread
/// round-robin id, so each worker thread has a stable home shard.
pub struct BufferPool {
    shards: [Mutex<Vec<Vec<PooledBuf>>>; SHARDS],
    bytes_cap: usize,
    retained: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
    drops: AtomicU64,
    recycled: AtomicU64,
}

/// Home shard for the calling thread: stable per thread, round-robin
/// across threads so workers spread evenly over the locks.
fn shard_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    IDX.with(|&i| i)
}

impl BufferPool {
    pub const fn new(bytes_cap: usize) -> BufferPool {
        BufferPool {
            shards: [const { Mutex::new(Vec::new()) }; SHARDS],
            bytes_cap,
            retained: AtomicUsize::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            drops: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// Pop a buffer of the request's class: home shard first, then the
    /// other shards (a miss pays a fresh allocation anyway, so the extra
    /// probes are cheap by comparison). Returns the buffer resized to
    /// `len` plus whether it was reused (stale contents) or fresh (zeroed).
    fn take_raw(&self, len: usize) -> (PooledBuf, bool) {
        if len == 0 {
            return (PooledBuf::empty(), false);
        }
        let cap = class_bytes(len);
        if let Some(idx) = class_index(cap) {
            let home = shard_index();
            for probe in 0..SHARDS {
                let popped = {
                    let mut shard = self.shards[(home + probe) % SHARDS].lock().unwrap();
                    shard.get_mut(idx).and_then(Vec::pop)
                };
                if let Some(mut b) = popped {
                    self.retained.fetch_sub(cap, Ordering::Relaxed);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    b.set_len_within_cap(len);
                    return (b, true);
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        (PooledBuf::alloc_class(len), false)
    }

    /// A zeroed buffer of exactly `len` bytes, reusing a pooled allocation
    /// of the matching size class when one is available.
    pub fn take_zeroed(&self, len: usize) -> PooledBuf {
        let (mut b, reused) = self.take_raw(len);
        if reused {
            b.as_mut_slice().fill(0);
        }
        b
    }

    /// A buffer of exactly `len` bytes whose contents are **unspecified**
    /// (stale data from a previous use) — for consumers that overwrite
    /// every byte before reading (fold's `copy_from_slice`, matmul's
    /// `fill(0)` + accumulate). Skips the re-zeroing pass of
    /// [`Self::take_zeroed`], which is pure overhead on those paths. The
    /// whole class capacity is zero-initialized at allocation, so no
    /// uninitialized memory is ever exposed.
    pub fn take_for_overwrite(&self, len: usize) -> PooledBuf {
        self.take_raw(len).0
    }

    /// Return a buffer to the pool. Dropped (and counted) when it has no
    /// backing allocation, is outside the class range, or would push total
    /// retained bytes past the cap.
    pub fn recycle(&self, buf: PooledBuf) {
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        let Some(idx) = class_index(cap) else {
            self.drops.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let prev = self.retained.fetch_add(cap, Ordering::Relaxed);
        if prev + cap > self.bytes_cap {
            self.retained.fetch_sub(cap, Ordering::Relaxed);
            self.drops.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.recycled.fetch_add(1, Ordering::Relaxed);
        let mut shard = self.shards[shard_index()].lock().unwrap();
        while shard.len() <= idx {
            shard.push(Vec::new());
        }
        shard[idx].push(buf);
    }

    /// Buffers currently pooled across all shards (tests / introspection).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().iter().map(Vec::len).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current counters (retained bytes from the shards only; per-thread
    /// caches are bounded slack outside the cap).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            drops: self.drops.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            retained_bytes: self.retained.load(Ordering::Relaxed),
            buffers: self.len(),
        }
    }
}

static GLOBAL: BufferPool = BufferPool::new(DEFAULT_BYTES_CAP);

thread_local! {
    /// Tiny per-thread front cache for the process-wide pool: lane-sized
    /// take/recycle pairs inside one worker skip the shard lock entirely.
    static TLS_CACHE: RefCell<Vec<PooledBuf>> = const { RefCell::new(Vec::new()) };
}

fn tls_take(len: usize) -> Option<PooledBuf> {
    if len == 0 || len > TLS_MAX_CLASS_BYTES {
        return None;
    }
    TLS_CACHE.with(|c| {
        let mut c = c.borrow_mut();
        let i = c.iter().position(|b| b.capacity() >= len)?;
        Some(c.swap_remove(i))
    })
}

/// Try to cache `buf` on this thread; hands it back when it doesn't fit.
fn tls_put(buf: PooledBuf) -> Option<PooledBuf> {
    if buf.capacity() == 0 || buf.capacity() > TLS_MAX_CLASS_BYTES {
        return Some(buf);
    }
    TLS_CACHE.with(move |c| {
        let mut c = c.borrow_mut();
        if c.len() < TLS_MAX_ENTRIES {
            c.push(buf);
            None
        } else {
            Some(buf)
        }
    })
}

/// The process-wide pool used by the decode and proxy paths.
pub fn global() -> &'static BufferPool {
    &GLOBAL
}

/// [`BufferPool::take_zeroed`] on the process-wide pool, fronted by the
/// per-thread cache.
pub fn take_zeroed(len: usize) -> PooledBuf {
    if let Some(mut b) = tls_take(len) {
        GLOBAL.hits.fetch_add(1, Ordering::Relaxed);
        b.set_len_within_cap(len);
        b.as_mut_slice().fill(0);
        return b;
    }
    GLOBAL.take_zeroed(len)
}

/// [`BufferPool::take_for_overwrite`] on the process-wide pool, fronted by
/// the per-thread cache.
pub fn take_for_overwrite(len: usize) -> PooledBuf {
    if let Some(mut b) = tls_take(len) {
        GLOBAL.hits.fetch_add(1, Ordering::Relaxed);
        b.set_len_within_cap(len);
        return b;
    }
    GLOBAL.take_for_overwrite(len)
}

/// [`BufferPool::recycle`] on the process-wide pool, fronted by the
/// per-thread cache.
pub fn recycle(buf: PooledBuf) {
    if let Some(b) = tls_put(buf) {
        GLOBAL.recycle(b);
    } else {
        GLOBAL.recycled.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_invariant() {
        let pool = BufferPool::new(16 << 20);
        for len in [1usize, 63, 64, 65, 1000, 4096, 100_000, 1 << 20] {
            let b = pool.take_zeroed(len);
            assert_eq!(b.as_ptr() as usize % ALIGN, 0, "take_zeroed({len}) misaligned");
            assert_eq!(b.len(), len);
            pool.recycle(b);
            let b2 = pool.take_for_overwrite(len);
            assert_eq!(b2.as_ptr() as usize % ALIGN, 0, "take_for_overwrite({len}) misaligned");
            assert_eq!(b2.len(), len);
        }
    }

    #[test]
    fn take_is_zeroed_after_recycle() {
        let pool = BufferPool::new(1 << 20);
        let mut b = pool.take_zeroed(100);
        b.iter_mut().for_each(|x| *x = 0xAB);
        pool.recycle(b);
        let b2 = pool.take_zeroed(50);
        assert_eq!(b2.len(), 50);
        assert!(b2.iter().all(|&x| x == 0), "reused buffer must be re-zeroed");
    }

    #[test]
    fn reuses_allocation_within_class() {
        let pool = BufferPool::new(1 << 20);
        let b = pool.take_zeroed(1024);
        let ptr = b.as_ptr();
        pool.recycle(b);
        // 512 rounds up to the same 1 KiB class, so the allocation returns
        let b2 = pool.take_zeroed(512);
        assert_eq!(b2.as_ptr(), ptr, "should reuse the pooled allocation");
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn classes_do_not_mix() {
        let pool = BufferPool::new(16 << 20);
        let big = pool.take_zeroed(1 << 20);
        pool.recycle(big);
        // a small request must not consume (and waste) the 1 MiB buffer
        let small = pool.take_zeroed(1024);
        assert!(small.capacity() <= 2048);
        assert_eq!(pool.len(), 1, "the large buffer must stay pooled");
        // and the large request gets it back
        let big2 = pool.take_for_overwrite(1 << 20);
        assert_eq!(big2.capacity(), 1 << 20);
        assert_eq!(pool.len(), 0);
    }

    #[test]
    fn bytes_cap_enforced_mixed_sizes() {
        let pool = BufferPool::new(64 << 10);
        for i in 0..64 {
            let len = if i % 3 == 0 { 32 << 10 } else { 4 << 10 };
            pool.recycle(PooledBuf::alloc_class(len));
            assert!(
                pool.stats().retained_bytes <= 64 << 10,
                "retained bytes exceeded the cap at iteration {i}"
            );
        }
        let s = pool.stats();
        assert!(s.drops > 0, "overflow recycles must be dropped");
        assert!(s.recycled > 0);
        // one huge outlier cannot pin memory either
        pool.recycle(PooledBuf::alloc_class(1 << 20));
        assert!(pool.stats().retained_bytes <= 64 << 10);
    }

    #[test]
    fn take_for_overwrite_reuses_without_zeroing() {
        let pool = BufferPool::new(1 << 20);
        let mut b = pool.take_zeroed(128);
        b.iter_mut().for_each(|x| *x = 0xCD);
        let ptr = b.as_ptr();
        pool.recycle(b);
        let b2 = pool.take_for_overwrite(100);
        assert_eq!(b2.len(), 100);
        assert_eq!(b2.as_ptr(), ptr, "must reuse the pooled allocation");
        assert!(b2.iter().all(|&x| x == 0xCD), "contents intentionally stale");
        // a different-class request gets a fresh (zeroed) allocation
        pool.recycle(b2);
        let b3 = pool.take_for_overwrite(4096);
        assert_eq!(b3.len(), 4096);
        assert!(b3.iter().all(|&x| x == 0), "fresh allocation is zeroed");
    }

    #[test]
    fn zero_len_take_ok() {
        let pool = BufferPool::new(1 << 20);
        let b = pool.take_zeroed(0);
        assert!(b.is_empty());
        pool.recycle(b); // no backing allocation — silently dropped
        assert_eq!(pool.len(), 0);
    }

    #[test]
    fn stats_add_up_under_concurrency() {
        // 8 threads × 10k take/recycle: no panic, counters consistent,
        // cap respected throughout.
        let pool = std::sync::Arc::new(BufferPool::new(8 << 20));
        let mut handles = Vec::new();
        for t in 0u8..8 {
            let p = std::sync::Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000usize {
                    let len = 1 + (i * 37 + t as usize * 101) % 8000;
                    let mut b = p.take_zeroed(len);
                    assert_eq!(b.len(), len);
                    assert!(b.iter().all(|&x| x == 0));
                    b[0] = t;
                    p.recycle(b);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, 80_000, "every take is a hit or a miss");
        assert_eq!(s.recycled + s.drops, 80_000, "every recycle is kept or dropped");
        assert!(s.retained_bytes <= 8 << 20);
        assert_eq!(
            s.retained_bytes,
            pool.shards
                .iter()
                .map(|sh| {
                    sh.lock().unwrap().iter().flatten().map(PooledBuf::capacity).sum::<usize>()
                })
                .sum::<usize>(),
            "retained counter must match the buffers actually held"
        );
    }

    #[test]
    fn pooled_buf_semantics() {
        let b = PooledBuf::from_slice(&[1, 2, 3]);
        assert_eq!(b, vec![1u8, 2, 3]);
        assert_eq!(vec![1u8, 2, 3], b);
        assert_eq!(b.clone(), b);
        assert_eq!(b.to_vec(), vec![1, 2, 3]);
        let empty = PooledBuf::empty();
        assert!(empty.is_empty());
        assert_eq!(empty.capacity(), 0);
        // nested comparisons (test suites compare Vec<PooledBuf> against
        // Vec<Vec<u8>> rebuilt-stripe fixtures)
        let outs = vec![PooledBuf::from_slice(&[9, 9])];
        assert_eq!(outs, vec![vec![9u8, 9]]);
    }

    #[test]
    fn global_thread_cache_roundtrip() {
        // lane-sized buffers round-trip through the TLS front cache
        let b = take_for_overwrite(16 << 10);
        let ptr = b.as_ptr();
        recycle(b);
        let b2 = take_for_overwrite(16 << 10);
        assert_eq!(b2.as_ptr(), ptr, "TLS cache must serve the same-thread retake");
        assert_eq!(b2.as_ptr() as usize % ALIGN, 0);
        recycle(b2);
    }
}
