//! GF(2^8) arithmetic substrate — the ISA-L analogue.
//!
//! Everything the coding layer needs over the field GF(2^8) with the
//! standard polynomial `x^8 + x^4 + x^3 + x^2 + 1` (0x11D, the same field
//! ISA-L and most storage systems use):
//!
//! * [`tables`] — scalar field ops backed by compile-time exp/log tables.
//! * [`slice`] — the hot path: XOR and constant-multiply-accumulate over
//!   byte slices, dispatched through the engine.
//! * [`simd`] — SSSE3 / AVX2 / NEON split-nibble (`PSHUFB`-class) kernels.
//! * [`dispatch`] — runtime CPU-feature tier selection ([`Kernel`]) and the
//!   lane-striped parallel executor ([`GfEngine`]), including the batched
//!   multi-stripe mode ([`dispatch::CodingBatch`]).
//! * [`workpool`] — the persistent worker pool behind every striped and
//!   batched operation (long-lived threads, per-batch completion latch).
//! * [`pool`] — the aligned, size-classed recycled-buffer pool behind the
//!   repair and batch output paths.
//! * [`topo`] — best-effort CPU/cache/package topology detection sizing
//!   the non-temporal-store threshold and the worker-pinning plan.
//! * [`matrix`] — dense matrices over GF(2^8): product, rank, inversion,
//!   and structured constructors (Vandermonde, Cauchy) used by the code
//!   constructions.

pub mod dispatch;
pub mod matrix;
pub mod pool;
pub mod simd;
pub mod slice;
pub mod tables;
pub mod topo;
pub mod workpool;

pub use dispatch::{CodingBatch, GfEngine, Kernel};
pub use workpool::{BatchScope, WorkPool};
pub use matrix::Matrix;
pub use slice::{mul_acc_slice, mul_slice, xor_fold, xor_slice, NibbleTables};
pub use tables::{gf_div, gf_exp, gf_inv, gf_log, gf_mul, gf_pow};
