//! Slice-granularity GF(2^8) kernels — the coding hot path.
//!
//! These are the operations a proxy performs on 1 MB blocks, so they are the
//! CPU analogue of the paper's ISA-L library (§2.3.3) and the subject of
//! Figure 3(a)'s XOR-vs-MUL comparison:
//!
//! * [`xor_slice`] / [`xor_fold`] — pure-XOR coding (what *XOR locality*
//!   buys).
//! * [`mul_slice`] / [`mul_acc_slice`] — multiply by a field constant.
//!
//! Since the engine refactor these entry points dispatch through the
//! process-wide [`GfEngine`](super::dispatch::GfEngine) (SSSE3 / AVX2 /
//! NEON split-nibble kernels when the CPU has them); the `*_scalar`
//! functions below are the portable SWAR fallback tier and the reference
//! the SIMD tiers are differentially tested against. All kernels are
//! alignment-agnostic and handle arbitrary lengths.

use super::dispatch;
use super::tables::gf_mul;

/// `dst ^= src` on the selected engine tier.
pub fn xor_slice(dst: &mut [u8], src: &[u8]) {
    dispatch::engine().xor(dst, src);
}

/// `dst ^= src`, word-at-a-time SWAR — the scalar tier.
pub fn xor_slice_scalar(dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len(), "xor_slice length mismatch");
    // Split both into u64-aligned middles. chunks_exact compiles to clean
    // vectorizable loops without unsafe.
    let mut d = dst.chunks_exact_mut(8);
    let mut s = src.chunks_exact(8);
    for (dc, sc) in (&mut d).zip(&mut s) {
        let x = u64::from_ne_bytes(dc.try_into().unwrap())
            ^ u64::from_ne_bytes(sc.try_into().unwrap());
        dc.copy_from_slice(&x.to_ne_bytes());
    }
    for (db, sb) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *db ^= *sb;
    }
}

/// XOR-fold many sources into `dst` (which is overwritten):
/// `dst = srcs[0] ^ srcs[1] ^ ...`. This is the entire decode path for a
/// UniLRC single-block repair. Large blocks are striped across the
/// engine's worker threads.
pub fn xor_fold(dst: &mut [u8], srcs: &[&[u8]]) {
    dispatch::engine().fold_blocks(dst, srcs);
}

/// Per-constant split-nibble tables: `lo[x & 0xF] ^ hi[x >> 4] = c·x`.
///
/// These 32 bytes are exactly what the SIMD tiers feed to `PSHUFB` / `TBL`,
/// and what [`PlanCache`](crate::codes::plan_cache) precomputes per cached
/// decode-plan coefficient. The extra [`mx`](Self::mx) qword is the same
/// multiply expressed as an 8×8 GF(2) bit matrix — what the GFNI tier
/// feeds to `GF2P8AFFINEQB` instead of table lookups.
#[derive(Debug, Clone, Copy)]
pub struct NibbleTables {
    /// The constant these tables multiply by.
    pub c: u8,
    pub lo: [u8; 16],
    pub hi: [u8; 16],
    /// Bit matrix of `x ↦ c·x` in `GF2P8AFFINEQB` operand layout: qword
    /// byte `7−i` holds output-bit row `i`, whose bit `j` is bit `i` of
    /// `c·2^j` (multiplication by a constant is GF(2)-linear, so it is
    /// exactly one affine transform with zero offset).
    pub mx: u64,
}

impl NibbleTables {
    pub fn new(c: u8) -> Self {
        let mut lo = [0u8; 16];
        let mut hi = [0u8; 16];
        for i in 0..16u8 {
            lo[i as usize] = gf_mul(c, i);
            hi[i as usize] = gf_mul(c, i << 4);
        }
        let mut mx = [0u8; 8];
        for j in 0..8usize {
            let p = gf_mul(c, 1u8 << j);
            for i in 0..8usize {
                if (p >> i) & 1 == 1 {
                    mx[7 - i] |= 1u8 << j;
                }
            }
        }
        NibbleTables { c, lo, hi, mx: u64::from_le_bytes(mx) }
    }

    /// Tables for a whole coefficient matrix, row-major — the shape every
    /// cached/batched matmul consumes.
    pub fn for_rows<I>(rows: I) -> Vec<Vec<NibbleTables>>
    where
        I: IntoIterator,
        I::Item: AsRef<[u8]>,
    {
        rows.into_iter()
            .map(|r| r.as_ref().iter().map(|&c| NibbleTables::new(c)).collect())
            .collect()
    }

    #[inline]
    pub fn mul(&self, x: u8) -> u8 {
        self.lo[(x & 0xF) as usize] ^ self.hi[(x >> 4) as usize]
    }
}

/// `dst = c · src` over GF(2^8).
pub fn mul_slice(c: u8, src: &[u8], dst: &mut [u8]) {
    assert_eq!(dst.len(), src.len(), "mul_slice length mismatch");
    match c {
        0 => dst.fill(0),
        1 => dst.copy_from_slice(src),
        _ => {
            dst.fill(0);
            dispatch::engine().mul_acc(c, src, dst);
        }
    }
}

/// `dst ^= c · src` — the multiply-accumulate every matrix-style encode and
/// decode is built from (one call per nonzero generator coefficient) — on
/// the selected engine tier.
pub fn mul_acc_slice(c: u8, src: &[u8], dst: &mut [u8]) {
    dispatch::engine().mul_acc(c, src, dst);
}

/// `dst ^= c · src` on the scalar tier: SWAR bit-plane decomposition over
/// `u64` words (§Perf): `c·x = ⊕_b bit_b(x)·(c·2^b)`, with each bit-plane
/// widened to a byte mask by the carry-free `t·0xFF` trick — 4 ALU ops per
/// byte, no table loads, the scalar-register shape of the same idea the L1
/// Pallas kernel uses on the TPU VPU. Tail bytes fall back to nibble
/// tables. This is the reference the SIMD tiers are fuzzed against.
pub fn mul_acc_slice_scalar(c: u8, src: &[u8], dst: &mut [u8]) {
    assert_eq!(dst.len(), src.len(), "mul_acc_slice length mismatch");
    match c {
        0 => {}
        1 => xor_slice_scalar(dst, src),
        _ => mul_acc_swar(c, src, dst),
    }
}

const LSB: u64 = 0x0101_0101_0101_0101;

fn mul_acc_swar(c: u8, src: &[u8], dst: &mut [u8]) {
    // plane constants: c·2^b broadcast to all 8 lanes
    let mut cb = [0u64; 8];
    for (b, w) in cb.iter_mut().enumerate() {
        *w = (gf_mul(c, 1 << b) as u64).wrapping_mul(LSB);
    }
    let mut d = dst.chunks_exact_mut(8);
    let mut s = src.chunks_exact(8);
    for (dc, sc) in (&mut d).zip(&mut s) {
        let w = u64::from_ne_bytes(sc.try_into().unwrap());
        let mut acc = u64::from_ne_bytes(dc.try_into().unwrap());
        // unrolled: mask_b = ((w>>b) & LSB)·0xFF stays inside each byte
        // because each lane value is 0 or 1.
        acc ^= ((w & LSB).wrapping_mul(0xFF)) & cb[0];
        acc ^= (((w >> 1) & LSB).wrapping_mul(0xFF)) & cb[1];
        acc ^= (((w >> 2) & LSB).wrapping_mul(0xFF)) & cb[2];
        acc ^= (((w >> 3) & LSB).wrapping_mul(0xFF)) & cb[3];
        acc ^= (((w >> 4) & LSB).wrapping_mul(0xFF)) & cb[4];
        acc ^= (((w >> 5) & LSB).wrapping_mul(0xFF)) & cb[5];
        acc ^= (((w >> 6) & LSB).wrapping_mul(0xFF)) & cb[6];
        acc ^= (((w >> 7) & LSB).wrapping_mul(0xFF)) & cb[7];
        dc.copy_from_slice(&acc.to_ne_bytes());
    }
    let t = NibbleTables::new(c);
    for (db, &sb) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *db ^= t.mul(sb);
    }
}

/// Matrix-style coding primitive: given `rows × cols` coefficients and `cols`
/// equal-length source slices, compute each output row `i` as
/// `⊕_j coeff[i][j] · src[j]`. Outputs must be pre-sized to the block length.
///
/// This one function implements encode (coefficients = parity submatrix) and
/// multi-failure decode (coefficients = inverted repair matrix). It runs on
/// the process-wide engine: SIMD kernels plus lane-striped workers for
/// large blocks (source-major within each lane, so a cache-hot source lane
/// is scattered into all output rows before the next is streamed in).
/// Outputs may be `Vec<u8>` or pooled aligned buffers
/// ([`PooledBuf`](super::pool::PooledBuf)) — anything that derefs to a
/// pre-sized mutable byte slice.
pub fn gf_matmul_blocks<B: AsMut<[u8]> + Send>(coeff: &[&[u8]], srcs: &[&[u8]], outs: &mut [B]) {
    dispatch::engine().matmul_blocks(coeff, srcs, outs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    fn ref_mul_slice(c: u8, src: &[u8]) -> Vec<u8> {
        src.iter().map(|&x| gf_mul(c, x)).collect()
    }

    #[test]
    fn xor_slice_matches_bytewise() {
        let mut p = Prng::new(1);
        for len in [0, 1, 7, 8, 9, 63, 64, 65, 1000, 4096] {
            let a = p.bytes(len);
            let b = p.bytes(len);
            for f in [xor_slice, xor_slice_scalar] {
                let mut d = a.clone();
                f(&mut d, &b);
                let expect: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
                assert_eq!(d, expect, "len={len}");
            }
        }
    }

    #[test]
    fn xor_is_involution() {
        let mut p = Prng::new(2);
        let a = p.bytes(513);
        let b = p.bytes(513);
        let mut d = a.clone();
        xor_slice(&mut d, &b);
        xor_slice(&mut d, &b);
        assert_eq!(d, a);
    }

    #[test]
    fn xor_fold_many() {
        let mut p = Prng::new(3);
        let srcs: Vec<Vec<u8>> = (0..7).map(|_| p.bytes(129)).collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u8; 129];
        xor_fold(&mut out, &refs);
        let mut expect = vec![0u8; 129];
        for s in &srcs {
            for (e, &x) in expect.iter_mut().zip(s) {
                *e ^= x;
            }
        }
        assert_eq!(out, expect);
    }

    #[test]
    fn nibble_tables_match_gf_mul_exhaustive() {
        for c in 0..=255u8 {
            let t = NibbleTables::new(c);
            assert_eq!(t.c, c);
            for x in 0..=255u8 {
                assert_eq!(t.mul(x), gf_mul(c, x), "c={c} x={x}");
            }
        }
    }

    /// Software model of `GF2P8AFFINEQB` (Intel SDM pseudocode): output bit
    /// `i` is the parity of `matrix.byte[7−i] AND x`.
    fn affine_apply(mx: u64, x: u8) -> u8 {
        let rows = mx.to_le_bytes();
        let mut out = 0u8;
        for i in 0..8usize {
            if (rows[7 - i] & x).count_ones() & 1 == 1 {
                out |= 1u8 << i;
            }
        }
        out
    }

    #[test]
    fn affine_matrix_matches_gf_mul_exhaustive() {
        // Validates the GFNI operand layout on every CPU, including ones
        // without the instruction — the hardware tier is additionally
        // fuzzed against scalar in tests/gf_simd.rs where available.
        for c in 0..=255u8 {
            let t = NibbleTables::new(c);
            for x in 0..=255u8 {
                assert_eq!(affine_apply(t.mx, x), gf_mul(c, x), "c={c} x={x}");
            }
        }
        // c=1 must be the canonical GFNI identity matrix
        assert_eq!(NibbleTables::new(1).mx, 0x0102_0408_1020_4080);
    }

    #[test]
    fn mul_slice_matches_scalar() {
        let mut p = Prng::new(4);
        let src = p.bytes(777);
        for c in [0u8, 1, 2, 3, 0x1D, 0xFF, 142] {
            let mut dst = vec![0u8; 777];
            mul_slice(c, &src, &mut dst);
            assert_eq!(dst, ref_mul_slice(c, &src), "c={c}");
        }
    }

    #[test]
    fn mul_acc_slice_accumulates() {
        let mut p = Prng::new(5);
        let src = p.bytes(300);
        let init = p.bytes(300);
        for c in [0u8, 1, 97] {
            for f in [mul_acc_slice, mul_acc_slice_scalar] {
                let mut dst = init.clone();
                f(c, &src, &mut dst);
                let expect: Vec<u8> = init
                    .iter()
                    .zip(&src)
                    .map(|(&d, &s)| d ^ gf_mul(c, s))
                    .collect();
                assert_eq!(dst, expect, "c={c}");
            }
        }
    }

    #[test]
    fn mul_slice_is_linear() {
        // c·(a ⊕ b) = c·a ⊕ c·b on slices
        let mut p = Prng::new(6);
        let a = p.bytes(256);
        let b = p.bytes(256);
        let c = 0x53;
        let mut ab = a.clone();
        xor_slice(&mut ab, &b);
        let mut left = vec![0u8; 256];
        mul_slice(c, &ab, &mut left);
        let mut ra = vec![0u8; 256];
        let mut rb = vec![0u8; 256];
        mul_slice(c, &a, &mut ra);
        mul_slice(c, &b, &mut rb);
        xor_slice(&mut ra, &rb);
        assert_eq!(left, ra);
    }

    #[test]
    fn gf_matmul_blocks_small() {
        // 2x3 coefficient matrix against hand-computed scalar result.
        let mut p = Prng::new(7);
        let srcs: Vec<Vec<u8>> = (0..3).map(|_| p.bytes(64)).collect();
        let srefs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let c0 = [1u8, 2, 3];
        let c1 = [0u8, 255, 7];
        let mut outs = vec![vec![0u8; 64]; 2];
        gf_matmul_blocks(&[&c0, &c1], &srefs, &mut outs);
        for b in 0..64 {
            let e0 = gf_mul(1, srcs[0][b]) ^ gf_mul(2, srcs[1][b]) ^ gf_mul(3, srcs[2][b]);
            let e1 = gf_mul(255, srcs[1][b]) ^ gf_mul(7, srcs[2][b]);
            assert_eq!(outs[0][b], e0);
            assert_eq!(outs[1][b], e1);
        }
    }

    #[test]
    fn gf_matmul_blocks_empty_sources() {
        let mut outs: Vec<Vec<u8>> = vec![];
        gf_matmul_blocks(&[], &[], &mut outs);
        assert!(outs.is_empty());
    }
}
