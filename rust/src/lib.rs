//! # UniLRC — Wide Locally Recoverable Codes with Unified Locality
//!
//! A reproduction of *"New Wide Locally Recoverable Codes with Unified
//! Locality"* (Xu et al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-storage-system coordinator:
//!   code constructions (UniLRC and the ALRC/OLRC/ULRC baselines), cluster
//!   topology and placement (ECWide, one-group-one-cluster), the theoretical
//!   analysis suite (recovery-cost metrics, MTTDL Markov model), and a
//!   virtual-time DSS prototype (coordinator / proxies / client over a
//!   bandwidth-constrained simulated network).
//! * **L2/L1 (build-time Python)** — JAX encode/decode graphs calling Pallas
//!   GF(2^8) kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime** — loads the artifacts through the PJRT C API (`xla` crate)
//!   so the request path never touches Python.
//!
//! Start with [`codes::spec::Scheme`] and the `examples/` directory.

pub mod analysis;
pub mod bench_util;
pub mod cli;
pub mod client;
pub mod codes;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gf;
pub mod placement;
pub mod prng;
pub mod proxy;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod stats;
