//! Configuration files for experiments and deployments.
//!
//! A hand-rolled TOML-subset parser (`serde`/`toml` are unavailable in
//! this offline build): `[sections]`, `key = value` with string / integer /
//! float / boolean values, `#` comments. Enough to express every knob of
//! [`ExpConfig`](crate::experiments::ExpConfig) and the §6 Setup
//! parameters; see `configs/paper.toml`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed configuration: `section.key → value` (top-level keys live in
/// the "" section).
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<(String, String), Value>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value {val:?}", lineno + 1))?;
            let prev = cfg
                .values
                .insert((section.clone(), key.trim().to_string()), value);
            if prev.is_some() {
                bail!("line {}: duplicate key {:?}", lineno + 1, key.trim());
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Get `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key).and_then(Value::as_usize)
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(Value::as_f64)
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(Value::as_bool)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(Value::as_str)
    }

    /// All keys of a section (for validation / error messages).
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.values
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse {s:?}")
}

/// Build and install the process-wide GF engine from optional kernel /
/// thread / batch-chunk / streaming-store / pinning overrides (shared by
/// the CLI flags and config-file keys; the engine freezes at first
/// install, so late overrides warn via `origin`). `chunk_kb = 0`
/// explicitly selects the adaptive chunk policy; `nt_kb` takes the
/// [`crate::gf::dispatch::parse_nt_kb`] grammar (a KiB threshold, `0` =
/// always stream, `auto`, `off`).
pub fn install_gf_engine(
    kernel: Option<&str>,
    threads: Option<usize>,
    chunk_kb: Option<usize>,
    nt_kb: Option<&str>,
    pin: Option<bool>,
    origin: &str,
) -> Result<()> {
    use crate::gf::dispatch::{self, GfEngine, Kernel};
    if kernel.is_none()
        && threads.is_none()
        && chunk_kb.is_none()
        && nt_kb.is_none()
        && pin.is_none()
    {
        return Ok(());
    }
    let mut engine = GfEngine::from_env();
    if let Some(k) = kernel {
        let k = Kernel::parse(k)
            .with_context(|| format!("bad gf kernel {k:?} (try `unilrc engine`)"))?;
        engine = engine.with_kernel(k);
    }
    if let Some(t) = threads {
        engine = engine.with_threads(t);
    }
    if let Some(kb) = chunk_kb {
        engine = engine.with_chunk(kb * 1024);
    }
    if let Some(v) = nt_kb {
        let t = dispatch::parse_nt_kb(v)
            .with_context(|| format!("bad gf nt threshold {v:?} (want KiB, `auto`, or `off`)"))?;
        engine = engine.with_nt(t);
    }
    if let Some(p) = pin {
        engine = engine.with_pin(p);
    }
    if !dispatch::install(engine) {
        eprintln!("note: GF engine already initialized — {origin} overrides ignored");
    }
    Ok(())
}

/// Set the decode-plan cache TTL in milliseconds on the global cache
/// (0 disables expiry). Shared by `--plan-ttl-ms`, `UNILRC_PLAN_TTL_MS`,
/// and the `[experiment] plan_ttl_ms` config key.
pub fn apply_plan_ttl(ms: u64) {
    let ttl = (ms > 0).then(|| std::time::Duration::from_millis(ms));
    crate::codes::plan_cache::global().set_ttl(ttl);
}

/// Build an experiment config from a file (CLI `--config`): recognized
/// keys under `[experiment]`: `scheme`, `block_kb`, `stripes`,
/// `cross_gbps`, `aggregated`, `backend`, `seed`, the GF engine knobs
/// `gf_kernel` (auto|scalar|ssse3|avx2|avx512|gfni|neon) / `gf_threads`
/// (worker-pool size) / `gf_chunk_kb` (batch task granularity; 0 =
/// adaptive) / `gf_nt_kb` (streaming-store threshold in KiB, or
/// `"auto"`/`"off"`) / `gf_pin` (pin pool workers to CPUs),
/// `plan_ttl_ms` (decode-plan cache TTL; 0 disables expiry),
/// and `plan_warmup` (prefetch decode plans for the fault trace's
/// predicted failure patterns — experiment 7).
pub fn experiment_config(cfg: &Config) -> Result<crate::experiments::ExpConfig> {
    use crate::codes::spec::Scheme;
    let mut e = crate::experiments::ExpConfig::default();
    // gf_nt_kb accepts both a bare KiB integer and the "auto"/"off" strings
    let nt_kb = match cfg.get("experiment", "gf_nt_kb") {
        Some(Value::Str(s)) => Some(s.clone()),
        Some(Value::Int(i)) => Some(i.to_string()),
        Some(v) => bail!("bad gf_nt_kb {v:?} (want KiB, \"auto\", or \"off\")"),
        None => None,
    };
    install_gf_engine(
        cfg.get_str("experiment", "gf_kernel"),
        cfg.get_usize("experiment", "gf_threads"),
        cfg.get_usize("experiment", "gf_chunk_kb"),
        nt_kb.as_deref(),
        cfg.get_bool("experiment", "gf_pin"),
        "config",
    )?;
    if let Some(ms) = cfg.get_usize("experiment", "plan_ttl_ms") {
        apply_plan_ttl(ms as u64);
    }
    if let Some(s) = cfg.get_str("experiment", "scheme") {
        e.scheme = Scheme::parse(s).with_context(|| format!("bad scheme {s:?}"))?;
    }
    if let Some(kb) = cfg.get_usize("experiment", "block_kb") {
        e.block_size = kb * 1024;
    }
    if let Some(s) = cfg.get_usize("experiment", "stripes") {
        e.stripes = s;
    }
    if let Some(g) = cfg.get_f64("experiment", "cross_gbps") {
        e.cross_gbps = g;
    }
    if let Some(a) = cfg.get_bool("experiment", "aggregated") {
        e.aggregated = a;
    }
    if let Some(s) = cfg.get_usize("experiment", "seed") {
        e.seed = s as u64;
    }
    match cfg.get("experiment", "plan_warmup") {
        Some(Value::Bool(b)) => {
            e.plan_warmup = if *b {
                crate::experiments::WarmupMode::Trace
            } else {
                crate::experiments::WarmupMode::Off
            };
        }
        Some(Value::Str(s)) => {
            e.plan_warmup = crate::experiments::WarmupMode::parse(s)
                .with_context(|| format!("bad plan_warmup {s:?} (off|trace|learned)"))?;
        }
        Some(v) => bail!("bad plan_warmup {v:?} (off|trace|learned or a boolean)"),
        None => {}
    }
    if let Some(spec) = cfg.get_str("topology", "clusters") {
        // same grammar as --topology (one shared parser; the CLI layer
        // validates the final scheme/topology pair per family)
        e.topology = Some(
            crate::experiments::parse_topology_spec(spec)
                .with_context(|| format!("bad [topology] clusters {spec:?}"))?,
        );
    }
    if cfg.get_str("experiment", "backend") == Some("pjrt") {
        e = e.with_pjrt()?;
    }
    Ok(e)
}

/// Apply the `[elastic]` section onto an experiment-8 config: recognized
/// keys `add_nodes`, `drain_nodes`, `add_clusters`, `cluster_nodes`
/// (0 = match the largest existing cluster), `fault_horizon_hours`
/// (post-scale fault replay; 0 disables). Explicit CLI flags override.
pub fn apply_elastic_keys(cfg: &Config, e: &mut crate::experiments::ElasticConfig) {
    if let Some(v) = cfg.get_usize("elastic", "add_nodes") {
        e.add_nodes = v;
    }
    if let Some(v) = cfg.get_usize("elastic", "drain_nodes") {
        e.drain_nodes = v;
    }
    if let Some(v) = cfg.get_usize("elastic", "add_clusters") {
        e.add_clusters = v;
    }
    if let Some(v) = cfg.get_usize("elastic", "cluster_nodes") {
        e.cluster_nodes = v;
    }
    if let Some(v) = cfg.get_f64("elastic", "fault_horizon_hours") {
        e.fault_horizon_hours = v;
    }
}

/// Apply the `[durability]` section onto an experiment-9 config:
/// recognized keys `wal_sync_every` (fsync once per this many committed
/// WAL groups — group commit), `snapshot_every` (manifest snapshot + log
/// truncation cadence in committed ops), `add_nodes`, `drain_nodes`,
/// `add_clusters`, `fault_ops` (scenario shape), `crash_cap` (crash
/// positions tested per family; 0 = all). The `UNILRC_WAL_SYNC_EVERY`
/// environment variable and explicit CLI flags override these, in that
/// order.
pub fn apply_durability_keys(cfg: &Config, d: &mut crate::experiments::DurabilitySimConfig) {
    if let Some(v) = cfg.get_usize("durability", "wal_sync_every") {
        d.wal_sync_every = v;
    }
    if let Some(v) = cfg.get_usize("durability", "snapshot_every") {
        d.snapshot_every = v;
    }
    if let Some(v) = cfg.get_usize("durability", "add_nodes") {
        d.add_nodes = v;
    }
    if let Some(v) = cfg.get_usize("durability", "drain_nodes") {
        d.drain_nodes = v;
    }
    if let Some(v) = cfg.get_usize("durability", "add_clusters") {
        d.add_clusters = v;
    }
    if let Some(v) = cfg.get_usize("durability", "fault_ops") {
        d.fault_ops = v;
    }
    if let Some(v) = cfg.get_usize("durability", "crash_cap") {
        d.crash_cap = v;
    }
}

/// Apply the `[migration]` section onto an experiment-10 config:
/// recognized keys `rate_mbps` (token-bucket refill rate for background
/// moves, megabits/s), `burst_kb` (bucket depth), `backoff_base_ms` /
/// `backoff_cap_ms` / `max_attempts` (capped exponential retry before an
/// event parks as retryable), `add_nodes`, `drain_nodes`, `add_clusters`
/// (crash-sweep scenario shape), `crash_cap` (crash positions tested per
/// family; 0 = all), `fg_reads` (foreground probes per throttle rate in
/// the interference curve). Explicit CLI flags override these.
pub fn apply_migration_keys(cfg: &Config, m: &mut crate::experiments::MigrationSimConfig) {
    if let Some(v) = cfg.get_f64("migration", "rate_mbps") {
        m.rate_mbps = v;
    }
    if let Some(v) = cfg.get_usize("migration", "burst_kb") {
        m.burst_kb = v;
    }
    if let Some(v) = cfg.get_f64("migration", "backoff_base_ms") {
        m.backoff_base_ms = v;
    }
    if let Some(v) = cfg.get_f64("migration", "backoff_cap_ms") {
        m.backoff_cap_ms = v;
    }
    if let Some(v) = cfg.get_usize("migration", "max_attempts") {
        m.max_attempts = v;
    }
    if let Some(v) = cfg.get_usize("migration", "add_nodes") {
        m.add_nodes = v;
    }
    if let Some(v) = cfg.get_usize("migration", "drain_nodes") {
        m.drain_nodes = v;
    }
    if let Some(v) = cfg.get_usize("migration", "add_clusters") {
        m.add_clusters = v;
    }
    if let Some(v) = cfg.get_usize("migration", "crash_cap") {
        m.crash_cap = v;
    }
    if let Some(v) = cfg.get_usize("migration", "fg_reads") {
        m.fg_reads = v;
    }
}

/// Apply the `[faults]` section onto an experiment-7 config: recognized
/// keys `horizon_hours`, `node_mttf_hours`, `node_mttr_hours`,
/// `cluster_mttf_hours`, `cluster_mttr_hours` (hours; a zero MTTF
/// disables that event class), `tenants`, `objects_per_tenant`,
/// `reads_per_event`, `measure_cap`. Explicit CLI flags override these.
pub fn apply_fault_keys(cfg: &Config, f: &mut crate::experiments::FaultSimConfig) {
    if let Some(v) = cfg.get_f64("faults", "horizon_hours") {
        f.fault.horizon_hours = v;
    }
    if let Some(v) = cfg.get_f64("faults", "node_mttf_hours") {
        f.fault.node_mttf_hours = v;
    }
    if let Some(v) = cfg.get_f64("faults", "node_mttr_hours") {
        f.fault.node_mttr_hours = v;
    }
    if let Some(v) = cfg.get_f64("faults", "cluster_mttf_hours") {
        f.fault.cluster_mttf_hours = v;
    }
    if let Some(v) = cfg.get_f64("faults", "cluster_mttr_hours") {
        f.fault.cluster_mttr_hours = v;
    }
    if let Some(v) = cfg.get_usize("faults", "tenants") {
        f.tenants = v;
    }
    if let Some(v) = cfg.get_usize("faults", "objects_per_tenant") {
        f.objects_per_tenant = v;
    }
    if let Some(v) = cfg.get_usize("faults", "reads_per_event") {
        f.reads_per_event = v;
    }
    if let Some(v) = cfg.get_usize("faults", "measure_cap") {
        f.measure_cap = v;
    }
}

/// Apply the `[scrub]` section onto an experiment-11 config. Sweep axes
/// are comma-separated hour lists (`intervals_hours = "12,48"`,
/// `sector_mtte_hours = "50,200"`); scalar keys `node_kb`,
/// `rate_mb_per_hour`, `burst_kb`, `tick_hours` size the per-pass work
/// and the shared background token bucket. The base node/cluster clocks
/// come from the `[faults]` keys via the exp7 plumbing; explicit CLI
/// flags override everything here.
pub fn apply_scrub_keys(
    cfg: &Config,
    s: &mut crate::experiments::ScrubSimConfig,
) -> anyhow::Result<()> {
    if let Some(v) = cfg.get_str("scrub", "intervals_hours") {
        s.intervals_hours = parse_hour_list(v, "intervals_hours")?;
    }
    if let Some(v) = cfg.get_str("scrub", "sector_mtte_hours") {
        s.sector_mtte_hours = parse_hour_list(v, "sector_mtte_hours")?;
    }
    if let Some(v) = cfg.get_usize("scrub", "node_kb") {
        s.node_bytes = v as u64 * 1024;
    }
    if let Some(v) = cfg.get_f64("scrub", "rate_mb_per_hour") {
        s.rate_bytes_per_hour = v * (1 << 20) as f64;
    }
    if let Some(v) = cfg.get_f64("scrub", "burst_kb") {
        s.burst_bytes = v * 1024.0;
    }
    if let Some(v) = cfg.get_f64("scrub", "tick_hours") {
        s.tick_hours = v;
    }
    Ok(())
}

/// Parse a comma-separated list of hour values (`"12,48"`) — the sweep
/// axes of the exp11 grid, shared by the `[scrub]` section and the
/// `--scrub-intervals-hours` / `--sector-mtte-hours` flags.
pub fn parse_hour_list(spec: &str, what: &str) -> anyhow::Result<Vec<f64>> {
    let vals: Vec<f64> = spec
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad {what} entry {t:?} (want hours, e.g. 12,48)"))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!vals.is_empty(), "{what} must name at least one sweep point");
    Ok(vals)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# paper §6 setup
title = "unilrc"         # inline comment
[experiment]
scheme = "210"
block_kb = 1024
stripes = 4
cross_gbps = 1.0
aggregated = true
seed = 42

[mttdl]
nodes = 400
epsilon = 0.1
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("", "title"), Some("unilrc"));
        assert_eq!(c.get_str("experiment", "scheme"), Some("210"));
        assert_eq!(c.get_usize("experiment", "block_kb"), Some(1024));
        assert_eq!(c.get_f64("experiment", "cross_gbps"), Some(1.0));
        assert_eq!(c.get_bool("experiment", "aggregated"), Some(true));
        assert_eq!(c.get_usize("mttdl", "nodes"), Some(400));
        assert_eq!(c.get(&"nope".to_string(), "x"), None);
    }

    #[test]
    fn experiment_config_roundtrip() {
        let c = Config::parse(SAMPLE).unwrap();
        let e = experiment_config(&c).unwrap();
        assert_eq!(e.scheme.n, 210);
        assert_eq!(e.block_size, 1024 * 1024);
        assert_eq!(e.stripes, 4);
        assert!(e.aggregated);
        assert_eq!(e.seed, 42);
    }

    #[test]
    fn gf_engine_keys_accepted() {
        let c = Config::parse("[experiment]\ngf_kernel = \"auto\"").unwrap();
        assert!(experiment_config(&c).is_ok());
        let bad = Config::parse("[experiment]\ngf_kernel = \"mmx\"").unwrap();
        assert!(experiment_config(&bad).is_err());
    }

    #[test]
    fn gf_chunk_key_accepted() {
        // explicit granularity and the 0 = adaptive sentinel both parse
        let c = Config::parse("[experiment]\ngf_chunk_kb = 256").unwrap();
        assert!(experiment_config(&c).is_ok());
        let adaptive = Config::parse("[experiment]\ngf_chunk_kb = 0").unwrap();
        assert!(experiment_config(&adaptive).is_ok());
    }

    #[test]
    fn gf_nt_and_pin_keys_accepted() {
        // integer KiB, the "auto"/"off" strings, and the pin boolean all
        // parse; garbage is rejected with a pointed error
        for text in [
            "[experiment]\ngf_nt_kb = 8192",
            "[experiment]\ngf_nt_kb = 0",
            "[experiment]\ngf_nt_kb = \"auto\"",
            "[experiment]\ngf_nt_kb = \"off\"",
            "[experiment]\ngf_pin = false",
        ] {
            let c = Config::parse(text).unwrap();
            assert!(experiment_config(&c).is_ok(), "{text}");
        }
        let bad = Config::parse("[experiment]\ngf_nt_kb = \"sometimes\"").unwrap();
        assert!(experiment_config(&bad).is_err());
        let bad = Config::parse("[experiment]\ngf_nt_kb = true").unwrap();
        assert!(experiment_config(&bad).is_err());
    }

    #[test]
    fn plan_ttl_key_accepted() {
        // 0 disables expiry; both forms must parse and apply cleanly.
        let c = Config::parse("[experiment]\nplan_ttl_ms = 5000").unwrap();
        assert!(experiment_config(&c).is_ok());
        let off = Config::parse("[experiment]\nplan_ttl_ms = 0").unwrap();
        assert!(experiment_config(&off).is_ok());
        crate::codes::plan_cache::global().set_ttl(None); // leave global state clean
    }

    #[test]
    fn plan_warmup_key_accepted() {
        use crate::experiments::WarmupMode;
        let on = Config::parse("[experiment]\nplan_warmup = true").unwrap();
        assert_eq!(experiment_config(&on).unwrap().plan_warmup, WarmupMode::Trace);
        let off = Config::parse("[experiment]\nplan_warmup = false").unwrap();
        assert_eq!(experiment_config(&off).unwrap().plan_warmup, WarmupMode::Off);
        let learned = Config::parse("[experiment]\nplan_warmup = \"learned\"").unwrap();
        assert_eq!(experiment_config(&learned).unwrap().plan_warmup, WarmupMode::Learned);
        let bad = Config::parse("[experiment]\nplan_warmup = \"maybe\"").unwrap();
        assert!(experiment_config(&bad).is_err());
    }

    #[test]
    fn topology_section_parses_cluster_sizes() {
        // shape-level parsing only here — per-family feasibility is the
        // CLI layer's job (experiments::validate_topology)
        let c = Config::parse("[topology]\nclusters = \"9, 9, 8\"").unwrap();
        assert_eq!(experiment_config(&c).unwrap().topology, Some(vec![9, 9, 8]));
        let bad = Config::parse("[topology]\nclusters = \"9,zero\"").unwrap();
        assert!(experiment_config(&bad).is_err());
        let zero = Config::parse("[topology]\nclusters = \"9,0\"").unwrap();
        assert!(experiment_config(&zero).is_err());
    }

    #[test]
    fn elastic_section_applies_over_defaults() {
        let c = Config::parse(
            "[elastic]\nadd_nodes = 4\ndrain_nodes = 1\ncluster_nodes = 6\n\
             fault_horizon_hours = 0",
        )
        .unwrap();
        let mut e = crate::experiments::ElasticConfig::default();
        let d = crate::experiments::ElasticConfig::default();
        apply_elastic_keys(&c, &mut e);
        assert_eq!(e.add_nodes, 4);
        assert_eq!(e.drain_nodes, 1);
        assert_eq!(e.cluster_nodes, 6);
        assert_eq!(e.fault_horizon_hours, 0.0);
        assert_eq!(e.add_clusters, d.add_clusters);
    }

    #[test]
    fn faults_section_applies_over_defaults() {
        let c = Config::parse(
            "[faults]\nhorizon_hours = 500.0\nnode_mttf_hours = 50\n\
             cluster_mttf_hours = 0\ntenants = 2\nmeasure_cap = 4",
        )
        .unwrap();
        let mut f = crate::experiments::FaultSimConfig::default();
        let defaults = crate::experiments::FaultSimConfig::default();
        apply_fault_keys(&c, &mut f);
        assert_eq!(f.fault.horizon_hours, 500.0);
        assert_eq!(f.fault.node_mttf_hours, 50.0);
        assert_eq!(f.fault.cluster_mttf_hours, 0.0);
        assert_eq!(f.tenants, 2);
        assert_eq!(f.measure_cap, 4);
        assert_eq!(f.fault.node_mttr_hours, defaults.fault.node_mttr_hours);
        assert_eq!(f.reads_per_event, defaults.reads_per_event);
    }

    #[test]
    fn scrub_section_applies_over_defaults() {
        let c = Config::parse(
            "[scrub]\nintervals_hours = \"6, 24,96\"\nsector_mtte_hours = \"40\"\n\
             node_kb = 512\nrate_mb_per_hour = 64.0\ntick_hours = 0.5",
        )
        .unwrap();
        let mut s = crate::experiments::ScrubSimConfig::default();
        let defaults = crate::experiments::ScrubSimConfig::default();
        apply_scrub_keys(&c, &mut s).unwrap();
        assert_eq!(s.intervals_hours, vec![6.0, 24.0, 96.0]);
        assert_eq!(s.sector_mtte_hours, vec![40.0]);
        assert_eq!(s.node_bytes, 512 * 1024);
        assert_eq!(s.rate_bytes_per_hour, 64.0 * (1 << 20) as f64);
        assert_eq!(s.tick_hours, 0.5);
        assert_eq!(s.burst_bytes, defaults.burst_bytes);
    }

    #[test]
    fn hour_list_rejects_garbage() {
        assert!(parse_hour_list("12,oops", "x").is_err());
        assert!(parse_hour_list("", "x").is_err());
        assert_eq!(parse_hour_list(" 7.5 ", "x").unwrap(), vec![7.5]);
    }

    #[test]
    fn durability_section_applies_over_defaults() {
        let c = Config::parse(
            "[durability]\nwal_sync_every = 1\nsnapshot_every = 16\ncrash_cap = 10\n\
             fault_ops = 2",
        )
        .unwrap();
        let mut d = crate::experiments::DurabilitySimConfig::default();
        let defaults = crate::experiments::DurabilitySimConfig::default();
        apply_durability_keys(&c, &mut d);
        assert_eq!(d.wal_sync_every, 1);
        assert_eq!(d.snapshot_every, 16);
        assert_eq!(d.crash_cap, 10);
        assert_eq!(d.fault_ops, 2);
        assert_eq!(d.add_nodes, defaults.add_nodes);
        assert_eq!(d.drain_nodes, defaults.drain_nodes);
        assert_eq!(d.add_clusters, defaults.add_clusters);
    }

    #[test]
    fn migration_section_applies_over_defaults() {
        let c = Config::parse(
            "[migration]\nrate_mbps = 100\nburst_kb = 256\nbackoff_base_ms = 5.0\n\
             max_attempts = 3\nfg_reads = 16",
        )
        .unwrap();
        let mut m = crate::experiments::MigrationSimConfig::default();
        let defaults = crate::experiments::MigrationSimConfig::default();
        apply_migration_keys(&c, &mut m);
        assert_eq!(m.rate_mbps, 100.0);
        assert_eq!(m.burst_kb, 256);
        assert_eq!(m.backoff_base_ms, 5.0);
        assert_eq!(m.max_attempts, 3);
        assert_eq!(m.fg_reads, 16);
        assert_eq!(m.backoff_cap_ms, defaults.backoff_cap_ms);
        assert_eq!(m.crash_cap, defaults.crash_cap);
        assert_eq!(m.add_nodes, defaults.add_nodes);
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(Config::parse("a = 1\na = 2").is_err());
        assert!(Config::parse("[open\n").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = @@").is_err());
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::parse("s = \"a # b\"").unwrap();
        assert_eq!(c.get_str("", "s"), Some("a # b"));
    }

    #[test]
    fn int_float_bool_edge_cases() {
        let c = Config::parse("i = -3\nf = 2.5e-3\nb = false").unwrap();
        assert_eq!(c.get("", "i"), Some(&Value::Int(-3)));
        assert!((c.get_f64("", "f").unwrap() - 2.5e-3).abs() < 1e-12);
        assert_eq!(c.get_bool("", "b"), Some(false));
        assert_eq!(c.get_usize("", "i"), None, "negative ints are not usize");
    }

    #[test]
    fn keys_listing() {
        let c = Config::parse(SAMPLE).unwrap();
        let mut ks = c.keys("experiment");
        ks.sort_unstable();
        assert_eq!(ks, vec!["aggregated", "block_kb", "cross_gbps", "scheme", "seed", "stripes"]);
    }
}
