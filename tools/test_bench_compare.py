#!/usr/bin/env python3
"""Unit tests for bench_compare.py gating semantics.

Run directly (`python3 tools/test_bench_compare.py`) or via
`python3 -m unittest discover tools` — no third-party deps.

The load-bearing case is the zero-baseline rule: a lower-is-better row
(retry counter, latency) whose baseline is 0.0 used to be exempt from
gating because a percentage of zero is undefined, which let a counter
going 0 -> 40 sail through CI. It now gates on the absolute rise.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_compare import main  # noqa: E402


def write_doc(dirname, filename, rows):
    path = os.path.join(dirname, filename)
    with open(path, "w") as f:
        json.dump({"bench": "test", "results": rows}, f)
    return path


def thr(name, mib):
    return {"name": name, "mib_per_s": mib}


def val(name, v, unit="count", better=None):
    row = {"name": name, "value": v, "unit": unit}
    if better is not None:
        row["better"] = better
    return row


class BenchCompareGate(unittest.TestCase):
    def run_gate(self, base_rows, curr_rows, *extra):
        with tempfile.TemporaryDirectory() as d:
            base = write_doc(d, "base.json", base_rows)
            curr = write_doc(d, "curr.json", curr_rows)
            return main([base, curr, *extra])

    def test_clean_run_passes(self):
        rc = self.run_gate(
            [thr("gf/mul", 1000.0), val("mig/retries", 2.0)],
            [thr("gf/mul", 990.0), val("mig/retries", 2.0)],
        )
        self.assertEqual(rc, 0)

    def test_throughput_drop_fails(self):
        rc = self.run_gate([thr("gf/mul", 1000.0)], [thr("gf/mul", 700.0)])
        self.assertEqual(rc, 1)

    def test_lower_is_better_rise_fails(self):
        rc = self.run_gate([val("serve/p99", 10.0, "ms")], [val("serve/p99", 15.0, "ms")])
        self.assertEqual(rc, 1)

    def test_zero_baseline_rise_now_gates(self):
        # the original bug: 0.0 baseline -> any current value passed
        rc = self.run_gate([val("mig/retries", 0.0)], [val("mig/retries", 40.0)])
        self.assertEqual(rc, 1)

    def test_zero_baseline_small_jitter_passes(self):
        # rises within the absolute slack stay informational
        rc = self.run_gate([val("mig/retries", 0.0)], [val("mig/retries", 1.0)])
        self.assertEqual(rc, 0)

    def test_zero_baseline_slack_is_tunable(self):
        rows = ([val("mig/retries", 0.0)], [val("mig/retries", 3.0)])
        self.assertEqual(self.run_gate(*rows, "--zero-baseline-slack", "5"), 0)
        self.assertEqual(self.run_gate(*rows, "--zero-baseline-slack", "2"), 1)

    def test_zero_baseline_throughput_never_gates(self):
        # higher-is-better from zero can only have improved
        rc = self.run_gate([thr("gf/mul", 0.0)], [thr("gf/mul", 500.0)])
        self.assertEqual(rc, 0)

    def test_pool_latency_row_rise_fails(self):
        # the memory-system rows: ns/op latency with an explicit
        # lower-is-better marker gates exactly like an inferred value row
        rows = (
            [val("pool/take-recycle-8t/sharded", 100.0, "ns", better="lower")],
            [val("pool/take-recycle-8t/sharded", 150.0, "ns", better="lower")],
        )
        self.assertEqual(self.run_gate(*rows), 1)

    def test_pool_latency_row_drop_passes(self):
        rows = (
            [val("pool/take-recycle-8t/sharded", 150.0, "ns", better="lower")],
            [val("pool/take-recycle-8t/sharded", 80.0, "ns", better="lower")],
        )
        self.assertEqual(self.run_gate(*rows), 0)

    def test_better_higher_overrides_value_inference(self):
        # a value row marked higher-is-better must not gate on a rise...
        rows = (
            [val("pool/hit-rate", 0.5, "ratio", better="higher")],
            [val("pool/hit-rate", 0.9, "ratio", better="higher")],
        )
        self.assertEqual(self.run_gate(*rows), 0)
        # ...and must gate on a drop
        rows = (
            [val("pool/hit-rate", 0.9, "ratio", better="higher")],
            [val("pool/hit-rate", 0.5, "ratio", better="higher")],
        )
        self.assertEqual(self.run_gate(*rows), 1)

    def test_new_and_gone_rows_are_not_fatal(self):
        rc = self.run_gate([thr("old/case", 100.0)], [thr("new/case", 100.0)])
        self.assertEqual(rc, 0)

    def test_missing_baseline_skips_gate(self):
        with tempfile.TemporaryDirectory() as d:
            curr = write_doc(d, "curr.json", [thr("gf/mul", 1.0)])
            rc = main([os.path.join(d, "absent.json"), curr])
        self.assertEqual(rc, 0)


if __name__ == "__main__":
    unittest.main()
