#!/usr/bin/env python3
"""Render the rolling bench baselines into a perf-trajectory SVG.

Maintains a history file (JSONL, one line per CI run) next to the cached
baselines and draws, for each bench, every case's throughput across runs
*indexed to its first recorded value* — a flat line at 100% is "no
change", dips are regressions, the single shared axis works for cases
whose absolute MiB/s differ by orders of magnitude. The largest movers
get the categorical colors and the legend; every other case stays as a
gray context line, so the chart stays readable at dozens of cases.

Pure stdlib — CI runners need nothing beyond python3. A text summary
table is printed to stdout (the accessible/table view of the same data).

Usage (CI):
    bench_plot.py --history bench-baseline/history.jsonl \
        --append BENCH_gf.json BENCH_pool.json --label "$GITHUB_RUN_NUMBER" \
        --out bench-trajectory.svg

Usage (local, re-render only):
    bench_plot.py --history bench-baseline/history.jsonl --out t.svg
"""

import argparse
import json
import os
import sys

# Reference categorical palette (fixed slot order, never cycled): movers
# beyond the highlight budget fold into gray context lines instead of
# minting new hues.
SERIES = ["#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4", "#008300"]
CONTEXT = "#d6d5d1"  # non-highlighted case lines
SURFACE = "#fcfcfb"
GRID = "#e8e7e3"
BASELINE = "#b6b5b0"  # the 100% reference rule
TEXT_PRIMARY = "#0b0b0b"
TEXT_SECONDARY = "#52514e"

PANEL_W = 960
PLOT_H = 240
MARGIN_L = 64
MARGIN_R = 24
TITLE_H = 44
AXIS_H = 34
LEGEND_ROW_H = 18


def esc(s):
    return s.replace("&", "&amp;").replace("<", "&lt;").replace(">", "&gt;")


def load_history(path):
    entries = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    entries.append(json.loads(line))
                except json.JSONDecodeError:
                    print("warning: skipping corrupt history line", file=sys.stderr)
    return entries


def append_run(entries, bench_files, label, max_runs):
    benches = {}
    for path in bench_files:
        with open(path) as f:
            doc = json.load(f)
        name = doc.get("bench") or os.path.basename(path)
        # throughput rows carry mib_per_s; direct-value rows (latency
        # percentiles, counters, the pool take/recycle ns/op pair) carry
        # value — both index fine as percent-of-first-run series
        benches[name] = {
            row["name"]: row.get("mib_per_s", row.get("value", 0.0))
            for row in doc.get("results", [])
        }
    entries.append({"label": label or str(len(entries) + 1), "benches": benches})
    return entries[-max_runs:]


def save_history(entries, path):
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        for e in entries:
            f.write(json.dumps(e) + "\n")


def collect_series(entries, bench):
    """case -> list of per-run values (None where the case is absent)."""
    cases = {}
    for i, e in enumerate(entries):
        for name, mib in e.get("benches", {}).get(bench, {}).items():
            cases.setdefault(name, [None] * len(entries))[i] = mib
    return cases


def indexed(values):
    """Percent-of-first-recorded-value, None preserved."""
    base = next((v for v in values if v), None)
    if not base:
        return [None] * len(values)
    return [None if v is None else 100.0 * v / base for v in values]


def nice_ticks(lo, hi):
    span = hi - lo
    for step in (5, 10, 20, 25, 50, 100, 200):
        if span / step <= 6:
            break
    first = int(lo // step) * step
    return [t for t in range(first, int(hi) + step, step) if lo <= t <= hi]


def render_panel(svg, y0, bench, cases, labels, highlight_n):
    idx = {name: indexed(vals) for name, vals in sorted(cases.items())}
    flat = [v for vals in idx.values() for v in vals if v is not None]
    if not flat:
        return y0
    lo = min(85.0, min(flat) - 5.0)
    hi = max(115.0, max(flat) + 5.0)
    nruns = len(labels)

    def x(i):
        if nruns == 1:
            return MARGIN_L + (PANEL_W - MARGIN_L - MARGIN_R) / 2
        return MARGIN_L + (PANEL_W - MARGIN_L - MARGIN_R) * i / (nruns - 1)

    def y(v):
        return y0 + TITLE_H + PLOT_H * (1 - (v - lo) / (hi - lo))

    # movers: largest |last - 100| get the categorical slots, fixed order
    def last(vals):
        return next((v for v in reversed(vals) if v is not None), 100.0)

    movers = sorted(idx, key=lambda n: abs(last(idx[n]) - 100.0), reverse=True)
    colored = movers[:highlight_n]
    color_of = {n: SERIES[i] for i, n in enumerate(colored)}

    svg.append(
        f'<text x="{MARGIN_L}" y="{y0 + 20}" fill="{TEXT_PRIMARY}" '
        f'font-size="15" font-weight="600">{esc(bench)}</text>'
    )
    svg.append(
        f'<text x="{MARGIN_L}" y="{y0 + 36}" fill="{TEXT_SECONDARY}" '
        f'font-size="11">% of first recorded run · '
        f"{len(idx)} cases · {nruns} runs</text>"
    )

    for t in nice_ticks(lo, hi):
        yy = y(t)
        stroke = BASELINE if t == 100 else GRID
        svg.append(
            f'<line x1="{MARGIN_L}" y1="{yy:.1f}" x2="{PANEL_W - MARGIN_R}" '
            f'y2="{yy:.1f}" stroke="{stroke}" stroke-width="1"/>'
        )
        svg.append(
            f'<text x="{MARGIN_L - 8}" y="{yy + 4:.1f}" fill="{TEXT_SECONDARY}" '
            f'font-size="11" text-anchor="end">{t}%</text>'
        )

    # x labels: first, last, and a few in between
    shown = {0, nruns - 1}
    if nruns > 2:
        shown |= {nruns // 2}
    for i in sorted(shown):
        svg.append(
            f'<text x="{x(i):.1f}" y="{y0 + TITLE_H + PLOT_H + 18}" '
            f'fill="{TEXT_SECONDARY}" font-size="11" text-anchor="middle">'
            f"run {esc(str(labels[i]))}</text>"
        )

    def polyline(vals, color, width, opacity):
        pts = [(x(i), y(v)) for i, v in enumerate(vals) if v is not None]
        if not pts:
            return
        if len(pts) == 1:
            cx, cy = pts[0]
            svg.append(
                f'<circle cx="{cx:.1f}" cy="{cy:.1f}" r="4" fill="{color}" '
                f'opacity="{opacity}"/>'
            )
            return
        d = " ".join(f"{px:.1f},{py:.1f}" for px, py in pts)
        svg.append(
            f'<polyline points="{d}" fill="none" stroke="{color}" '
            f'stroke-width="{width}" stroke-linejoin="round" '
            f'stroke-linecap="round" opacity="{opacity}"/>'
        )

    # context lines first (under), highlighted movers on top
    for name in idx:
        if name not in color_of:
            polyline(idx[name], CONTEXT, 1.2, 0.9)
    for name in colored:
        polyline(idx[name], color_of[name], 2, 1.0)
        vals = idx[name]
        li = max(i for i, v in enumerate(vals) if v is not None) if any(
            v is not None for v in vals
        ) else None
        if li is not None:
            svg.append(
                f'<circle cx="{x(li):.1f}" cy="{y(vals[li]):.1f}" r="3.5" '
                f'fill="{color_of[name]}" stroke="{SURFACE}" stroke-width="2">'
                f"<title>{esc(name)}: {vals[li]:.1f}% of first run</title></circle>"
            )

    ly = y0 + TITLE_H + PLOT_H + AXIS_H
    for i, name in enumerate(colored):
        yy = ly + i * LEGEND_ROW_H
        pct = last(idx[name])
        svg.append(
            f'<rect x="{MARGIN_L}" y="{yy - 9}" width="10" height="10" rx="2" '
            f'fill="{color_of[name]}"/>'
        )
        svg.append(
            f'<text x="{MARGIN_L + 16}" y="{yy}" fill="{TEXT_PRIMARY}" '
            f'font-size="11">{esc(name[:70])}</text>'
        )
        svg.append(
            f'<text x="{PANEL_W - MARGIN_R}" y="{yy}" fill="{TEXT_SECONDARY}" '
            f'font-size="11" text-anchor="end">{pct:.1f}%</text>'
        )
    rest = len(idx) - len(colored)
    if rest > 0:
        yy = ly + len(colored) * LEGEND_ROW_H
        svg.append(
            f'<rect x="{MARGIN_L}" y="{yy - 9}" width="10" height="10" rx="2" '
            f'fill="{CONTEXT}"/>'
        )
        svg.append(
            f'<text x="{MARGIN_L + 16}" y="{yy}" fill="{TEXT_SECONDARY}" '
            f'font-size="11">{rest} further cases (within normal variance)</text>'
        )
    return ly + (len(colored) + (1 if rest else 0)) * LEGEND_ROW_H + 20


def render(entries, out, highlight_n):
    labels = [e.get("label", str(i + 1)) for i, e in enumerate(entries)]
    bench_names = []
    for e in entries:
        for b in e.get("benches", {}):
            if b not in bench_names:
                bench_names.append(b)

    svg = []
    y = 8
    if not bench_names:
        svg.append(
            f'<text x="24" y="40" fill="{TEXT_PRIMARY}" font-size="14">'
            "no bench history yet — the trajectory appears after the first "
            "recorded run</text>"
        )
        y = 80
    for bench in bench_names:
        cases = collect_series(entries, bench)
        y = render_panel(svg, y, bench, cases, labels, highlight_n)

    doc = (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{PANEL_W}" '
        f'height="{y}" viewBox="0 0 {PANEL_W} {y}" '
        f'font-family="system-ui, sans-serif">\n'
        f'<rect width="{PANEL_W}" height="{y}" fill="{SURFACE}"/>\n'
        + "\n".join(svg)
        + "\n</svg>\n"
    )
    with open(out, "w") as f:
        f.write(doc)
    print(f"wrote {out} ({len(bench_names)} panel(s), {len(entries)} run(s))")


def print_table(entries):
    if not entries:
        return
    last = entries[-1]
    for bench, cases in last.get("benches", {}).items():
        print(f"\n{bench} — latest run (label {last.get('label')}):")
        hist = collect_series(entries, bench)
        for name in sorted(cases):
            pct = indexed(hist[name])
            cur = next((v for v in reversed(pct) if v is not None), None)
            rel = f"{cur:6.1f}% of first" if cur is not None else "      new"
            print(f"  {name:<48} {cases[name]:>10.1f}  {rel}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", required=True, help="JSONL history file")
    ap.add_argument(
        "--append",
        nargs="*",
        default=[],
        metavar="BENCH.json",
        help="bench JSON artifacts to record as one new run",
    )
    ap.add_argument("--label", default=None, help="label for the appended run")
    ap.add_argument("--max-runs", type=int, default=60)
    ap.add_argument("--highlight", type=int, default=len(SERIES))
    ap.add_argument("--out", required=True, help="output SVG path")
    args = ap.parse_args()

    entries = load_history(args.history)
    if args.append:
        entries = append_run(entries, args.append, args.label, args.max_runs)
        save_history(entries, args.history)
    render(entries, args.out, min(args.highlight, len(SERIES)))
    print_table(entries)
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        sys.exit(0)  # stdout piped into head &c. — the artifact is written
