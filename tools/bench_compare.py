#!/usr/bin/env python3
"""Compare two bench JSON artifacts (BENCH_gf.json / BENCH_pool.json
schema) and fail on throughput regressions.

Usage:
    bench_compare.py BASELINE.json CURRENT.json [--max-regression 0.20]

A case regresses when its current MiB/s drops more than the threshold
below the baseline. Cases present in only one file are reported but never
fatal (benches evolve). Exit code 1 iff at least one regression exceeds
the threshold.
"""

import argparse
import json
import sys


def load_results(path):
    with open(path) as f:
        doc = json.load(f)
    return {row["name"]: row for row in doc.get("results", [])}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.20,
        help="fractional throughput drop that fails the check (default 0.20)",
    )
    args = ap.parse_args()

    base = load_results(args.baseline)
    curr = load_results(args.current)

    failures = []
    for name, row in sorted(curr.items()):
        if name not in base:
            print(f"  NEW     {name}: {row['mib_per_s']:.1f} MiB/s")
            continue
        b, c = base[name]["mib_per_s"], row["mib_per_s"]
        if b <= 0:
            continue
        delta = (c - b) / b
        status = "ok"
        if delta < -args.max_regression:
            status = "REGRESSION"
            failures.append((name, b, c, delta))
        print(f"  {status:<10} {name}: {b:.1f} -> {c:.1f} MiB/s ({delta:+.1%})")
    for name in sorted(set(base) - set(curr)):
        print(f"  GONE    {name} (was {base[name]['mib_per_s']:.1f} MiB/s)")

    if failures:
        print(
            f"\n{len(failures)} case(s) regressed more than "
            f"{args.max_regression:.0%} vs baseline:",
            file=sys.stderr,
        )
        for name, b, c, delta in failures:
            print(f"  {name}: {b:.1f} -> {c:.1f} MiB/s ({delta:+.1%})", file=sys.stderr)
        return 1
    print("\nno regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
