#!/usr/bin/env python3
"""Compare two bench JSON artifacts (BENCH_gf.json / BENCH_pool.json
schema) and fail on throughput regressions.

Usage:
    bench_compare.py BASELINE.json CURRENT.json [--max-regression 0.20]

A throughput case (`mib_per_s`) regresses when its current MiB/s drops
more than the threshold below the baseline. A direct-value case
(`value`/`unit` — latency percentiles, retry counters from the migration
interference sweep) regresses when its value *rises* more than the
threshold: those rows are lower-is-better. Either inference can be
overridden per row with `"better": "higher"|"lower"` (the buffer-pool
contention rows declare `lower` explicitly). A lower-is-better case whose
baseline is zero has no ratio, so it gates on the *absolute* rise
instead (`--zero-baseline-slack`, default 1.0) — a retries counter
going 0 -> 40 is a regression even though 0 admits no percentage.
Cases present in only one file are reported but never fatal (benches
evolve). Exit code 1 iff at least one regression exceeds a threshold.
"""

import argparse
import json
import sys


def load_results(path):
    with open(path) as f:
        doc = json.load(f)
    return {row["name"]: row for row in doc.get("results", [])}


def metric(row):
    """(value, unit, sign) — sign +1 when higher is better, -1 when lower.

    The direction is inferred from the row shape (`mib_per_s` rows are
    higher-is-better, `value` rows lower-is-better) unless the row carries
    an explicit `"better": "higher"|"lower"` — the memory-system rows
    (pool take/recycle ns/op) declare it so the inference never has to
    guess what a bare unit like "ns" means.
    """
    if "mib_per_s" in row:
        value, unit, sign = row["mib_per_s"], "MiB/s", 1
    else:
        value, unit, sign = row["value"], row.get("unit", ""), -1
    better = row.get("better")
    if better == "higher":
        sign = 1
    elif better == "lower":
        sign = -1
    return value, unit, sign


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.20,
        help="fractional throughput drop that fails the check (default 0.20)",
    )
    ap.add_argument(
        "--zero-baseline-slack",
        type=float,
        default=1.0,
        help="absolute rise that fails a lower-is-better case whose "
        "baseline is zero (default 1.0)",
    )
    args = ap.parse_args(argv)

    # A missing or empty baseline is the first run of a new bench (or a
    # wiped cache) — say so explicitly and pass, rather than failing on
    # the open or silently "passing" an empty comparison.
    try:
        base = load_results(args.baseline)
    except (FileNotFoundError, json.JSONDecodeError):
        print(f"no baseline yet at {args.baseline} — skipping gate")
        return 0
    if not base:
        print(f"baseline {args.baseline} has no result rows — skipping gate")
        return 0
    curr = load_results(args.current)

    failures = []
    for name, row in sorted(curr.items()):
        c, unit, sign = metric(row)
        if name not in base:
            print(f"  NEW     {name}: {c:.1f} {unit}")
            continue
        b, base_unit, base_sign = metric(base[name])
        if base_sign != sign:
            # row changed schema between runs — treat as new, nothing comparable
            print(f"  NEW     {name}: {c:.1f} {unit} (was {b:.1f} {base_unit})")
            continue
        if b <= 0:
            # a zero baseline (e.g. a retries counter at 0.0) has no ratio.
            # A higher-is-better row can only have improved; a
            # lower-is-better row rising from a clean baseline is exactly
            # the regression the ratio test is blind to, so it gates on
            # the absolute increase instead.
            if sign < 0 and c - b > args.zero_baseline_slack:
                failures.append((name, b, c, f"+{c - b:.1f} abs", unit))
                print(f"  REGRESSION {name}: {b:.1f} -> {c:.1f} {unit} (zero baseline)")
            elif c > 0:
                print(f"  moved   {name}: {b:.1f} -> {c:.1f} {unit} (zero baseline)")
            continue
        delta = (c - b) / b
        status = "ok"
        if sign * delta < -args.max_regression:
            status = "REGRESSION"
            failures.append((name, b, c, f"{delta:+.1%}", unit))
        print(f"  {status:<10} {name}: {b:.1f} -> {c:.1f} {unit} ({delta:+.1%})")
    for name in sorted(set(base) - set(curr)):
        b, unit, _ = metric(base[name])
        print(f"  GONE    {name} (was {b:.1f} {unit})")

    if failures:
        print(
            f"\n{len(failures)} case(s) regressed beyond threshold "
            f"({args.max_regression:.0%} relative, "
            f"{args.zero_baseline_slack:g} absolute on zero baselines):",
            file=sys.stderr,
        )
        for name, b, c, delta, unit in failures:
            print(f"  {name}: {b:.1f} -> {c:.1f} {unit} ({delta})", file=sys.stderr)
        return 1
    print("\nno regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
