"""Pallas kernels vs the pure-jnp/numpy oracle — the CORE L1 correctness
signal. Hypothesis sweeps shapes and contents; fixed cases pin the paper's
scheme dimensions."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import gf
from compile.kernels import gf256, ref


def _rand(rng, *shape):
    return rng.integers(0, 256, shape, dtype=np.uint8)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 12),
    k=st.integers(1, 24),
    b=st.sampled_from([1, 2, 16, 100, 256, 1000, 2048, 4096]),
    seed=st.integers(0, 2**31),
)
def test_gf_matmul_matches_oracle(m, k, b, seed):
    rng = np.random.default_rng(seed)
    coeff = _rand(rng, m, k)
    data = _rand(rng, k, b)
    out = np.asarray(gf256.gf_matmul(jnp.asarray(coeff), jnp.asarray(data)))
    assert np.array_equal(out, gf.gf_matmul(coeff, data))


@settings(max_examples=40, deadline=None)
@given(
    s=st.integers(1, 30),
    b=st.sampled_from([1, 7, 64, 500, 2048, 8192]),
    seed=st.integers(0, 2**31),
)
def test_xor_fold_matches_reduce(s, b, seed):
    rng = np.random.default_rng(seed)
    blocks = _rand(rng, s, b)
    out = np.asarray(gf256.xor_fold(jnp.asarray(blocks)))
    assert out.shape == (1, b)
    assert np.array_equal(out[0], np.bitwise_xor.reduce(blocks, axis=0))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 8),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_bitplanes_from_coeffs_matches_numpy(m, k, seed):
    rng = np.random.default_rng(seed)
    coeff = _rand(rng, m, k)
    bp_j = np.asarray(gf256.bitplanes_from_coeffs(jnp.asarray(coeff)))
    bp_n = gf.bitplanes(coeff)
    assert np.array_equal(bp_j, bp_n)
    # plane b really is c·2^b
    for b in range(8):
        expect = gf.gf_mul(coeff, np.full_like(coeff, gf.gf_pow(2, b)))
        assert np.array_equal(bp_n[:, :, b], expect), b


def test_ref_matches_numpy():
    rng = np.random.default_rng(7)
    coeff = _rand(rng, 6, 10)
    data = _rand(rng, 10, 333)
    assert np.array_equal(np.asarray(ref.ref_gf_matmul(coeff, data)), gf.gf_matmul(coeff, data))
    blocks = _rand(rng, 9, 128)
    assert np.array_equal(
        np.asarray(ref.ref_xor_fold(blocks)), np.bitwise_xor.reduce(blocks, axis=0)
    )


def test_scheme_shapes_exact():
    """Paper Table 2 dimensions through the kernel (small block)."""
    rng = np.random.default_rng(11)
    for m, k in [(12, 30), (24, 112), (30, 180)]:
        coeff = _rand(rng, m, k)
        data = _rand(rng, k, 4096)
        out = np.asarray(gf256.gf_matmul(jnp.asarray(coeff), jnp.asarray(data)))
        assert np.array_equal(out, gf.gf_matmul(coeff, data)), (m, k)


def test_nonuniform_tile_fallback():
    """Block sizes that don't divide B_TILE exercise _pick_tile."""
    rng = np.random.default_rng(13)
    coeff = _rand(rng, 2, 3)
    for b in [3000, 2049, 4097]:
        data = _rand(rng, 3, b)
        out = np.asarray(gf256.gf_matmul(jnp.asarray(coeff), jnp.asarray(data)))
        assert np.array_equal(out, gf.gf_matmul(coeff, data)), b


def test_zero_coefficients_and_data():
    coeff = np.zeros((3, 4), dtype=np.uint8)
    data = np.zeros((4, 64), dtype=np.uint8)
    out = np.asarray(gf256.gf_matmul(jnp.asarray(coeff), jnp.asarray(data)))
    assert not out.any()


def test_vmem_estimate_under_budget():
    """DESIGN.md §Hardware-Adaptation: the tile picker keeps every scheme's
    per-step working set inside a 16 MiB VMEM."""
    for m, k in [(12, 42), (24, 136), (30, 210), (30, 180)]:
        bt = gf256._pick_tile(65536, m, k)
        assert gf256.vmem_estimate_bytes(m, k, bt) < gf256.VMEM_BUDGET, (m, k, bt)
        assert 65536 % bt == 0
