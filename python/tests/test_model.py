"""L2 model tests: UniLRC construction properties and encode graphs."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import gf, model, unilrc


def test_params_match_theorem():
    for alpha, z in [(1, 3), (1, 6), (2, 8), (2, 10)]:
        n, k, r = unilrc.params(alpha, z)
        assert n == alpha * z * z + z
        assert k == alpha * z * (z - 1)
        assert r == alpha * z
        a = unilrc.parity_matrix(alpha, z)
        assert a.shape == (n - k, k)


def test_local_parity_is_xor_of_group():
    """§3.1: l_i = XOR(data segment i) ⊕ XOR(globals of group i)."""
    for alpha, z in [(1, 6), (2, 4)]:
        n, k, r = unilrc.params(alpha, z)
        a = unilrc.parity_matrix(alpha, z)
        rng = np.random.default_rng(1)
        data = rng.integers(0, 256, (k, 16), dtype=np.uint8)
        stripe = np.vstack([data, gf.gf_matmul(a, data)])
        g = alpha * z
        seg = k // z
        for i in range(z):
            lp = stripe[k + g + i]
            x = np.zeros(16, dtype=np.uint8)
            for j in range(i * seg, (i + 1) * seg):
                x ^= stripe[j]
            for gi in range(i * alpha, (i + 1) * alpha):
                x ^= stripe[k + gi]
            assert np.array_equal(lp, x), (alpha, z, i)


def test_group_xors_to_zero():
    """Every local group's blocks XOR to zero — the repair invariant."""
    alpha, z = 1, 6
    n, k, r = unilrc.params(alpha, z)
    a = unilrc.parity_matrix(alpha, z)
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, (k, 32), dtype=np.uint8)
    stripe = np.vstack([data, gf.gf_matmul(a, data)])
    seg = k // z
    for i in range(z):
        members = list(range(i * seg, (i + 1) * seg))
        members += [k + i]  # α=1: one global per group
        members += [k + z + i]  # local parity (g = z for α=1)
        acc = np.zeros(32, dtype=np.uint8)
        for m in members:
            acc ^= stripe[m]
        assert not acc.any(), i


def test_encode_graph_matches_reference():
    for alpha, z in [(1, 6), (2, 8)]:
        n, k, _ = unilrc.params(alpha, z)
        enc, (spec,) = model.make_encode(alpha, z, 1024)
        rng = np.random.default_rng(3)
        data = rng.integers(0, 256, (k, 1024), dtype=np.uint8)
        (out,) = jax.jit(enc)(jnp.asarray(data))
        assert np.array_equal(np.asarray(out), model.encode_reference(alpha, z, data))


def test_gf_decode_graph_inverts_encode():
    """Feed the inverse repair matrix as runtime coefficients."""
    alpha, z = 1, 6
    n, k, _ = unilrc.params(alpha, z)
    a = unilrc.parity_matrix(alpha, z)
    rng = np.random.default_rng(4)
    data = rng.integers(0, 256, (k, 512), dtype=np.uint8)
    parity = gf.gf_matmul(a, data)
    # "decode" the parities from data via the generic graph = re-encode
    dec, _ = model.make_gf_decode(n - k, k, 512)
    (out,) = jax.jit(dec)(jnp.asarray(a), jnp.asarray(data))
    assert np.array_equal(np.asarray(out), parity)


def test_xor_fold_graph_repairs_unilrc_block():
    """End-to-end single-block repair through the L2 fold graph."""
    alpha, z = 1, 6
    n, k, r = unilrc.params(alpha, z)
    a = unilrc.parity_matrix(alpha, z)
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, (k, 256), dtype=np.uint8)
    stripe = np.vstack([data, gf.gf_matmul(a, data)])
    # repair d0 from its group {d1..d4, g1, l1}
    srcs = np.stack([stripe[1], stripe[2], stripe[3], stripe[4], stripe[k], stripe[k + z]])
    fold, _ = model.make_xor_fold(srcs.shape[0], 256)
    (out,) = jax.jit(fold)(jnp.asarray(srcs))
    assert np.array_equal(np.asarray(out)[0], stripe[0])
