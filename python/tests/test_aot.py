"""AOT pipeline tests: lowering produces loadable HLO text with the right
entry layouts, and the manifest is consistent."""

import os
import subprocess
import sys

import pytest

from compile import aot


def test_to_hlo_text_smoke():
    from compile import model

    fold, args = model.make_xor_fold(3, 256)
    hlo = aot.to_hlo_text(fold, args)
    assert hlo.startswith("HloModule")
    assert "u8[3,256]" in hlo
    assert "u8[1,256]" in hlo.split("\n")[0]  # output in entry layout


def test_build_artifacts_complete():
    arts = list(aot.build_artifacts(1024))
    kinds = [a[0] for a in arts]
    assert kinds.count("encode") == 3
    assert kinds.count("gfdec") == 3
    expected_folds = len({s for v in aot.XOR_FOLD_SIZES.values() for s in v})
    assert kinds.count("xorfold") == expected_folds
    names = [a[1] for a in arts]
    assert len(names) == len(set(names)), "artifact names must be unique"
    for kind, name, params, hlo in arts:
        assert hlo.startswith("HloModule"), name
        assert "b" in params


def test_encode_artifact_shapes():
    arts = {a[1]: a for a in aot.build_artifacts(512)}
    kind, name, params, hlo = arts["encode_a1z6_b512"]
    head = hlo.split("\n")[0]
    assert "u8[30,512]" in head  # k data blocks in
    assert "u8[12,512]" in head  # n−k parities out


def test_only_flag_skips_manifest(tmp_path):
    """--only is a debug knob and must not clobber the full manifest."""
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--block", "512",
         "--only", "xorfold_s5_"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert not (tmp_path / "manifest.tsv").exists()
    emitted = list(tmp_path.glob("xorfold_s5_*.hlo.txt"))
    assert len(emitted) == 1


def test_manifest_format():
    """The checked-in manifest (built by `make artifacts`) is well-formed."""
    art = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "artifacts", "manifest.tsv")
    if not os.path.exists(art):
        pytest.skip("artifacts not built")
    lines = open(art).read().strip().split("\n")
    assert len(lines) == 20
    for line in lines:
        kind, name, fname, kv = line.split("\t")
        assert kind in ("encode", "gfdec", "xorfold")
        assert fname.endswith(".hlo.txt")
