"""Cross-language golden vectors: the rust CLI (`unilrc golden`) writes the
encoded stripe for a fixed message under each Table 2 UniLRC scheme; the
python construction must reproduce it byte-for-byte.

This pins the two independent implementations of the §3.2 generator
construction (rust/src/codes/unilrc.rs vs python/compile/unilrc.py) to each
other — regenerate with `cargo run --release -- golden --out
python/tests/golden_vectors.txt` if the construction intentionally changes.
"""

import os

import numpy as np
import pytest

from compile import gf, unilrc

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_vectors.txt")


def load_golden():
    cases = []
    with open(GOLDEN) as f:
        for line in f:
            alpha_s, z_s, bytes_s = line.split()
            cases.append((int(alpha_s), int(z_s), np.array([int(b) for b in bytes_s.split(",")], dtype=np.uint8)))
    return cases


@pytest.mark.skipif(not os.path.exists(GOLDEN), reason="golden vectors not generated")
@pytest.mark.parametrize("alpha,z,expect", load_golden() if os.path.exists(GOLDEN) else [])
def test_python_construction_matches_rust(alpha, z, expect):
    n, k, _ = unilrc.params(alpha, z)
    assert expect.shape == (n,)
    data = np.array([(j * 31 + 7) % 256 for j in range(k)], dtype=np.uint8)
    # systematic prefix
    assert np.array_equal(expect[:k], data)
    a = unilrc.parity_matrix(alpha, z)
    parity = gf.gf_matmul(a, data[:, None])[:, 0]
    assert np.array_equal(expect[k:], parity), f"α={alpha} z={z}"
