"""Field-law tests for the numpy GF(2^8) layer (compile/gf.py)."""

import numpy as np
import pytest

from compile import gf


def test_exp_log_roundtrip():
    for x in range(1, 256):
        assert gf.EXP[gf.LOG[x]] == x


def test_mul_identity_zero():
    xs = np.arange(256, dtype=np.uint8)
    assert np.array_equal(gf.gf_mul(xs, 1), xs)
    assert np.array_equal(gf.gf_mul(xs, 0), np.zeros(256, dtype=np.uint8))


def test_mul_matches_schoolbook_exhaustive():
    def slow(a, b):
        acc = 0
        while b:
            if b & 1:
                acc ^= a
            b >>= 1
            a <<= 1
            if a & 0x100:
                a ^= gf.POLY
        return acc

    a = np.repeat(np.arange(256, dtype=np.uint8), 256)
    b = np.tile(np.arange(256, dtype=np.uint8), 256)
    fast = gf.gf_mul(a, b)
    for i in range(0, 65536, 257):  # diagonal + spread sample
        assert fast[i] == slow(int(a[i]), int(b[i]))
    # full check on a dense subsample
    idx = np.arange(0, 65536, 7)
    slow_vals = np.array([slow(int(x), int(y)) for x, y in zip(a[idx], b[idx])], dtype=np.uint8)
    assert np.array_equal(fast[idx], slow_vals)


def test_mul_commutative_distributive():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, 1000, dtype=np.uint8)
    b = rng.integers(0, 256, 1000, dtype=np.uint8)
    c = rng.integers(0, 256, 1000, dtype=np.uint8)
    assert np.array_equal(gf.gf_mul(a, b), gf.gf_mul(b, a))
    assert np.array_equal(gf.gf_mul(a, b ^ c), gf.gf_mul(a, b) ^ gf.gf_mul(a, c))


def test_inv_and_pow():
    for a in range(1, 256):
        assert gf.gf_mul(a, gf.gf_inv(a)) == 1
        assert gf.gf_pow(a, 255) == 1
        assert gf.gf_pow(a, 2) == gf.gf_mul(a, a)
    assert gf.gf_pow(0, 3) == 0
    assert gf.gf_pow(7, 0) == 1
    with pytest.raises(AssertionError):
        gf.gf_inv(0)


def test_gf_matmul_identity():
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, (5, 64), dtype=np.uint8)
    eye = np.eye(5, dtype=np.uint8)
    assert np.array_equal(gf.gf_matmul(eye, data), data)


def test_nibble_tables_reconstruct_multiply():
    rng = np.random.default_rng(3)
    coeff = rng.integers(0, 256, (3, 4), dtype=np.uint8)
    tlo, thi = gf.nibble_tables(coeff)
    xs = rng.integers(0, 256, 100, dtype=np.uint8)
    for i in range(3):
        for j in range(4):
            expect = gf.gf_mul(np.full(100, coeff[i, j], dtype=np.uint8), xs)
            got = tlo[i, j][xs & 0xF] ^ thi[i, j][xs >> 4]
            assert np.array_equal(got, expect)
