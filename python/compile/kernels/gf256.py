"""Pallas GF(2^8) coding kernels (L1) — the coding hot-spot of the paper's
prototype, re-thought for TPU (DESIGN.md §Hardware-Adaptation).

Two kernels:

* :func:`gf_matmul_bitplanes` — coefficient-matrix × data-blocks over
  GF(2^8) using *bit-plane decomposition*: GF multiplication by a constant
  is GF(2)-linear, so ``c·x = ⊕_{b=0..7} bit_b(x) · (c·2^b)``. The kernel
  widens each data bit-plane to a byte mask and ANDs it with the
  precomputed plane constants — pure element-wise VPU work with **no
  gather**. (ISA-L's PSHUFB nibble trick is the x86 shape of the same idea;
  gathers are slow on the TPU VPU *and* the 16-entry-shuffle HLO gather is
  exactly what old PJRT runtimes disagree on, so the bit-plane form is both
  the faithful TPU adaptation and the version-stable interchange.)
* :func:`xor_fold` — XOR-reduce of S source blocks: the *entire* decode
  computation for UniLRC thanks to XOR locality (§2.3.3).

Plane constants come from :func:`bitplanes_from_coeffs` (in-graph, for
runtime coefficient matrices — repeated xtime, still gather-free) or from
``compile.gf.bitplanes`` (numpy, constant-folded into encode artifacts).

Both kernels tile the byte dimension with a BlockSpec grid so each step's
working set fits VMEM (see :func:`vmem_estimate_bytes`).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated through the interpret path and the
same HLO runs from rust.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Byte-dimension tile cap and the VMEM budget the tile must respect
# (DESIGN.md §Hardware-Adaptation: TPU VMEM ≈ 16 MiB).
B_TILE = 2048
VMEM_BUDGET = 16 * 1024 * 1024


def _pick_tile(b, m, k):
    """Largest tile ≤ B_TILE that divides b *and* keeps the per-step
    working set (plane constants + data tile + mask intermediates) under
    the VMEM budget — the BlockSpec schedule a real TPU lowering would use."""
    t = min(b, B_TILE)
    while t > 1 and vmem_estimate_bytes(m, k, t) > VMEM_BUDGET:
        t //= 2
    while b % t:
        t -= 1
    return t


def _xtime(x):
    """Multiply by the field generator 2 (one AES-style xtime step):
    ``(x << 1) ^ (0x1D if x & 0x80 else 0)`` — element-wise, no tables."""
    hi = (x >> 7).astype(jnp.uint8)  # 0 or 1
    return ((x << 1) ^ (hi * jnp.uint8(0x1D))).astype(jnp.uint8)


def bitplanes_from_coeffs(coeff):
    """(M,K) coefficient matrix → (M,K,8) plane constants, in-graph.

    ``bp[i,j,b] = coeff[i,j] · 2^b`` over GF(2^8), built by repeated
    :func:`_xtime` so the decode artifact needs no lookup tables.
    """
    coeff = jnp.asarray(coeff, dtype=jnp.uint8)
    planes = [coeff]
    for _ in range(7):
        planes.append(_xtime(planes[-1]))
    return jnp.stack(planes, axis=-1)


def _gf_matmul_kernel(bp_ref, data_ref, out_ref):
    """One grid step: out[M,Bt] = ⊕_j ⊕_b bit_b(data[j])·bp[·,j,b]."""
    data = data_ref[...]  # (K, Bt) uint8
    bp = bp_ref[...]  # (M, K, 8) uint8
    m = bp.shape[0]
    acc = jnp.zeros((m, data.shape[1]), dtype=jnp.uint8)
    for b in range(8):
        bit = (data >> b) & jnp.uint8(1)  # (K, Bt)
        mask = (jnp.uint8(0) - bit).astype(jnp.uint8)  # 0x00 / 0xFF
        contrib = bp[:, :, b][:, :, None] & mask[None, :, :]  # (M, K, Bt)
        acc = acc ^ jax.lax.reduce(contrib, jnp.uint8(0), jax.lax.bitwise_xor, (1,))
    out_ref[...] = acc


def gf_matmul_bitplanes(bp, data):
    """(M,K,8) plane constants × (K,B) data → (M,B) over GF(2^8)."""
    m, k, _ = bp.shape
    b = data.shape[1]
    bt = _pick_tile(b, m, k)
    return pl.pallas_call(
        _gf_matmul_kernel,
        grid=(b // bt,),
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, bt), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, b), jnp.uint8),
        interpret=True,
    )(bp, data)


def gf_matmul(coeff, data):
    """Convenience: runtime-coefficient GF matmul (planes built in-graph)."""
    return gf_matmul_bitplanes(bitplanes_from_coeffs(coeff), data)


def _xor_fold_kernel(src_ref, out_ref):
    src = src_ref[...]  # (S, Bt)
    # explicit XOR chain instead of lax.reduce: S is small and static, and
    # the unrolled chain fuses into one elementwise loop on the CPU PJRT
    # runtime where the u8 reduce does not (§Perf).
    acc = src[0]
    for j in range(1, src.shape[0]):
        acc = acc ^ src[j]
    out_ref[...] = acc[None, :]


def _pick_fold_tile(b, s):
    """Fold working set is just the (S,Bt) tile + output — allow much
    larger tiles than the matmul (fewer grid steps ⇒ lower per-call
    overhead on the CPU PJRT runtime, §Perf)."""
    t = min(b, VMEM_BUDGET // (2 * s))
    while b % t:
        t -= 1
    return t


def xor_fold(blocks):
    """XOR-fold (S,B) → (1,B): the UniLRC repair fast path."""
    s, b = blocks.shape
    bt = _pick_fold_tile(b, s)
    if bt == b:
        # single-tile case: skip the grid machinery entirely so the HLO is
        # one flat fused reduce (§Perf: the grid's dynamic-slice plumbing
        # costs more than the XOR itself on the CPU PJRT runtime).
        return pl.pallas_call(
            _xor_fold_kernel,
            out_shape=jax.ShapeDtypeStruct((1, b), jnp.uint8),
            interpret=True,
        )(blocks)
    return pl.pallas_call(
        _xor_fold_kernel,
        grid=(b // bt,),
        in_specs=[pl.BlockSpec((s, bt), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, bt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.uint8),
        interpret=True,
    )(blocks)


@functools.lru_cache(maxsize=None)
def vmem_estimate_bytes(m, k, bt=B_TILE):
    """Per-grid-step VMEM working set (DESIGN.md §Perf): plane constants +
    data tile + one (M,K,Bt) mask intermediate + accumulator/output tile."""
    planes = m * k * 8
    data = k * bt
    inter = m * k * bt  # one plane's contrib before its reduce
    out = 2 * m * bt  # acc + out
    return planes + data + inter + out
