"""Pure-jnp oracle for the Pallas kernels (the L1 correctness contract).

``ref_gf_matmul`` computes the GF(2^8) coefficient-matrix × data-blocks
product with plain log/exp-table gathers; ``ref_xor_fold`` is the XOR
reduce. Every Pallas kernel must match these bit-for-bit (pytest +
hypothesis sweeps in python/tests/).
"""

import jax.numpy as jnp
import numpy as np

from ..gf import EXP, LOG

_JEXP = jnp.asarray(EXP)
_JLOG = jnp.asarray(LOG)


def ref_gf_matmul(coeff, data):
    """(M,K) × (K,B) over GF(2^8), elementwise log/exp formulation."""
    coeff = jnp.asarray(coeff, dtype=jnp.uint8)
    data = jnp.asarray(data, dtype=jnp.uint8)
    logc = _JLOG[coeff].astype(jnp.int32)  # (M,K)
    logd = _JLOG[data].astype(jnp.int32)  # (K,B)
    prod = _JEXP[logc[:, :, None] + logd[None, :, :]]  # (M,K,B)
    zero = (coeff == 0)[:, :, None] | (data == 0)[None, :, :]
    prod = jnp.where(zero, jnp.uint8(0), prod)
    out = jnp.zeros((coeff.shape[0], data.shape[1]), dtype=jnp.uint8)
    for j in range(coeff.shape[1]):
        out = out ^ prod[:, j, :]
    return out


def ref_xor_fold(blocks):
    """XOR-fold (S,B) → (B,)."""
    blocks = jnp.asarray(blocks, dtype=jnp.uint8)
    out = jnp.zeros((blocks.shape[1],), dtype=jnp.uint8)
    for j in range(blocks.shape[0]):
        out = out ^ blocks[j]
    return out


def np_gf_matmul(coeff, data):
    """Numpy variant (no jax tracing) for hypothesis-heavy tests."""
    from .. import gf

    return gf.gf_matmul(np.asarray(coeff), np.asarray(data))
