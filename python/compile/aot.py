"""AOT lowering: JAX coding graphs → HLO *text* artifacts for the rust
runtime (python runs once at `make artifacts`, never on the request path).

HLO text — NOT ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs into ``artifacts/``:

* ``encode_a{α}z{z}_b{B}.hlo.txt``   — UniLRC encode per Table 2 scheme
* ``gfdec_m{M}_k{K}_b{B}.hlo.txt``   — generic coefficient-fed decode
* ``xorfold_s{S}_b{B}.hlo.txt``      — XOR-fold repair, one per source count
* ``manifest.tsv``                   — `kind name file key=val…` index

Usage: ``python -m compile.aot --out-dir ../artifacts [--block 65536]``
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model, unilrc

# Table 2 schemes: label → (α, z).
SCHEMES = {"42": (1, 6), "136": (2, 8), "210": (2, 10)}

# XOR-fold source counts needed per scheme: UniLRC r; ALRC k/l; OLRC
# k/l+g; ULRC group sizes −1 (see DESIGN.md §3 scheme table).
XOR_FOLD_SIZES = {
    "42": [5, 6, 7, 8, 25],
    "136": [14, 16, 18, 19, 78],
    "210": [18, 20, 22, 23, 87],
}

DEFAULT_BLOCK = 65536
# XOR-fold artifacts use bigger blocks: the op is streaming (no (M,K,B)
# intermediate), and larger blocks amortize PJRT per-call overhead (§Perf).
FOLD_BLOCK_FACTOR = 16


def to_hlo_text(fn, example_args):
    """Lower a jitted fn to HLO text via stablehlo → XlaComputation."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def build_artifacts(block):
    """Yield (kind, name, params, hlo_text) for every artifact."""
    for label, (alpha, z) in SCHEMES.items():
        n, k, r = unilrc.params(alpha, z)
        m = n - k

        enc, args = model.make_encode(alpha, z, block)
        yield (
            "encode",
            f"encode_a{alpha}z{z}_b{block}",
            {"scheme": label, "alpha": alpha, "z": z, "k": k, "m": m, "b": block},
            to_hlo_text(enc, args),
        )

        dec, args = model.make_gf_decode(m, n, block)
        yield (
            "gfdec",
            f"gfdec_m{m}_k{n}_b{block}",
            {"scheme": label, "m": m, "k": n, "b": block},
            to_hlo_text(dec, args),
        )

    fold_block = block * FOLD_BLOCK_FACTOR
    sizes = sorted({s for v in XOR_FOLD_SIZES.values() for s in v})
    for s in sizes:
        fold, args = model.make_xor_fold(s, fold_block)
        yield (
            "xorfold",
            f"xorfold_s{s}_b{fold_block}",
            {"s": s, "b": fold_block},
            to_hlo_text(fold, args),
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--block", type=int, default=DEFAULT_BLOCK)
    ap.add_argument("--only", help="emit artifacts whose name contains this substring")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for kind, name, params, hlo in build_artifacts(args.block):
        if args.only and args.only not in name:
            continue
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(hlo)
        kv = " ".join(f"{k}={v}" for k, v in params.items())
        manifest.append(f"{kind}\t{name}\t{fname}\t{kv}")
        print(f"wrote {fname} ({len(hlo)} chars)", file=sys.stderr)

    if args.only:
        # debug mode: don't clobber the full manifest with a subset
        print(f"{len(manifest)} artifacts (manifest NOT rewritten: --only)", file=sys.stderr)
    else:
        with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
            f.write("\n".join(manifest) + "\n")
        print(f"{len(manifest)} artifacts → {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
