"""Scalar GF(2^8) arithmetic (polynomial 0x11D) in numpy.

Build-time only. Mirrors rust/src/gf/tables.rs — the two implementations are
cross-checked through the golden vectors in python/tests/test_golden.py and
the PJRT round-trip integration test on the rust side.
"""

import numpy as np

POLY = 0x11D


def _build_tables():
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.uint8)
    x = 1
    for i in range(255):
        exp[i] = x
        exp[i + 255] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    return exp, log


EXP, LOG = _build_tables()


def gf_mul(a, b):
    """Element-wise GF(2^8) multiply of arrays (or scalars)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = EXP[LOG[a].astype(np.int32) + LOG[b].astype(np.int32)]
    return np.where((a == 0) | (b == 0), np.uint8(0), out)


def gf_pow(a, e):
    """a**e over GF(2^8) for scalar a."""
    if e == 0:
        return 1
    if a == 0:
        return 0
    return int(EXP[(int(LOG[a]) * e) % 255])


def gf_inv(a):
    assert a != 0
    return int(EXP[255 - int(LOG[a])])


def gf_matmul(coeff, data):
    """(M,K) x (K,B) GF(2^8) matrix product — the numpy oracle."""
    coeff = np.asarray(coeff, dtype=np.uint8)
    data = np.asarray(data, dtype=np.uint8)
    m, k = coeff.shape
    assert data.shape[0] == k
    out = np.zeros((m, data.shape[1]), dtype=np.uint8)
    for j in range(k):
        out ^= gf_mul(coeff[:, j : j + 1], data[j : j + 1, :])
    return out


def nibble_tables(coeff):
    """Split-nibble multiply tables for a coefficient matrix.

    Returns (tlo, thi), each (M, K, 16) uint8 with
    ``tlo[i,j,x] = coeff[i,j]*x`` and ``thi[i,j,x] = coeff[i,j]*(x<<4)``,
    so ``coeff[i,j]*v = tlo[i,j,v&15] ^ thi[i,j,v>>4]``.
    """
    coeff = np.asarray(coeff, dtype=np.uint8)
    lo = np.arange(16, dtype=np.uint8)
    hi = (np.arange(16, dtype=np.uint8) << 4).astype(np.uint8)
    tlo = gf_mul(coeff[..., None], lo[None, None, :])
    thi = gf_mul(coeff[..., None], hi[None, None, :])
    return tlo, thi


def bitplanes(coeff):
    """(M,K) coefficients → (M,K,8) plane constants: bp[i,j,b] = c·2^b."""
    coeff = np.asarray(coeff, dtype=np.uint8)
    planes = [coeff]
    for _ in range(7):
        x = planes[-1]
        hi = (x >> 7).astype(np.uint8)
        planes.append((((x.astype(np.uint16) << 1) & 0xFF).astype(np.uint8)
                       ^ (hi * np.uint8(0x1D))))
    return np.stack(planes, axis=-1)
