"""L2 — the JAX coding graphs the rust runtime executes via PJRT.

Three graph families, all calling the L1 Pallas kernels:

* :func:`make_encode` — per-scheme UniLRC encode with the generator
  constant-folded into nibble tables: ``(k,B) data → (n−k,B) parities``.
* :func:`make_gf_decode` — generic decode: ``((M,K) coeffs, (K,B) sources)
  → (M,B)``; rust inverts the small repair system and feeds coefficients
  at runtime, so one artifact per scheme decodes any erasure pattern (and
  encodes any *other* code family, which is how the baselines run through
  PJRT too).
* :func:`make_xor_fold` — ``(S,B) sources → (1,B)``: single-failure repair
  for every XOR-local plan.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import gf, unilrc
from .kernels import gf256


def make_encode(alpha, z, block):
    """UniLRC(α,z) encode graph and its example input shapes."""
    a = jnp.asarray(unilrc.parity_matrix(alpha, z))
    k = a.shape[1]

    def encode(data):  # (k, B) uint8 → (n−k, B) uint8
        # plane constants expanded in-graph from the 2-D generator constant
        # (3-D u8 constants mis-parse in the 0.5.1 HLO text reader).
        return (gf256.gf_matmul_bitplanes(gf256.bitplanes_from_coeffs(a), data),)

    spec = jax.ShapeDtypeStruct((k, block), jnp.uint8)
    return encode, (spec,)


def make_gf_decode(m, k, block):
    """Generic coefficient-fed GF(2^8) matmul graph (decode/encode-any)."""

    def decode(coeff, data):  # (m,k) u8, (k,B) u8 → (m,B) u8
        return (gf256.gf_matmul(coeff, data),)

    cspec = jax.ShapeDtypeStruct((m, k), jnp.uint8)
    dspec = jax.ShapeDtypeStruct((k, block), jnp.uint8)
    return decode, (cspec, dspec)


def make_xor_fold(s, block):
    """XOR-fold graph of S source blocks."""

    def fold(blocks):  # (S, B) u8 → (1, B) u8
        return (gf256.xor_fold(blocks),)

    spec = jax.ShapeDtypeStruct((s, block), jnp.uint8)
    return fold, (spec,)


def encode_reference(alpha, z, data):
    """Numpy reference encode used by tests and golden vectors."""
    a = unilrc.parity_matrix(alpha, z)
    return gf.gf_matmul(a, np.asarray(data, dtype=np.uint8))
