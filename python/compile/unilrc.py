"""UniLRC generator-matrix construction (§3.2) in Python.

An independent reimplementation of rust/src/codes/unilrc.rs used to
constant-fold the encode artifacts; the two are cross-checked via golden
vectors and the PJRT round-trip test.
"""

import numpy as np

from . import gf


def vandermonde(rows, points, start):
    """V[i][j] = points[j]**(start+i) over GF(2^8)."""
    v = np.zeros((rows, len(points)), dtype=np.uint8)
    for i in range(rows):
        for j, p in enumerate(points):
            v[i, j] = gf.gf_pow(p, start + i)
    return v


def distinct_points(count):
    assert count <= 255
    return [gf.gf_pow(2, i) for i in range(count)]


def parity_matrix(alpha, z):
    """The (n−k) × k parity submatrix A = [𝒢; 𝓛] of UniLRC(α, z)."""
    assert alpha >= 1 and z >= 2
    k = alpha * z * (z - 1)
    g = alpha * z
    assert k <= 255
    pts = distinct_points(k)
    gmat = vandermonde(g, pts, 1)  # Step 1: global parity rows
    seg = k // z
    lmat = np.zeros((z, k), dtype=np.uint8)
    for i in range(z):  # Step 3: fold α rows per group
        for row in range(i * alpha, (i + 1) * alpha):
            lmat[i] ^= gmat[row]
        lmat[i, i * seg : (i + 1) * seg] ^= 1  # Steps 2+4: couple segment
    return np.vstack([gmat, lmat])


def params(alpha, z):
    """(n, k, r) for UniLRC(α, z)."""
    k = alpha * z * (z - 1)
    return (alpha * z * z + z, k, alpha * z)
